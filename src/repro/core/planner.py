"""Auto-parallelisation — the survey's §4 search problem, three ways.

Search space: legal (dp, tp, pp, microbatches, seq_parallel, remat)
assignments for a chip count, evaluated by core/costmodel.estimate (the
"strategy evaluation" half of §4). Search methods mirror paper Table 3:

  * "exhaustive"  — PipeDream-style full enumeration,
  * "dp"          — Alpa-style two-level: dynamic programming over pipeline
                    stage cuts (from the operator graph) x ILP-lite choice
                    of intra-op degree per stage,
  * "mcmc"        — FlexFlow-style Markov-chain Monte-Carlo random walk.

All three return the same Plan record so benchmarks/bench_table3_search.py
can compare quality vs. search cost — the standardisation the survey's
Future Work section asks for.
"""
from __future__ import annotations

import dataclasses
import random
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.costmodel import (CostBreakdown, Degrees, Hardware, V5E,
                                  estimate)
from repro.core.opgraph import build_opgraph


@dataclass
class Plan:
    """A searched (or hand-specified) parallelisation strategy.

    Plans are EXECUTABLE: ``materialize(devices=...)`` turns the abstract
    ``Degrees`` into a validated ``(Strategy, Mesh)`` pair that
    ``repro.api.Session`` (and the launch drivers) run directly — the
    GSPMD/Alpa shape where one plan object flows from search into
    execution instead of dead-ending in a report.
    """
    degrees: Degrees
    cost: float                  # estimated step time (s)
    mfu: float
    fits: bool
    evaluations: int
    method: str
    stage_layers: Optional[List[List[int]]] = None
    breakdown: Optional[CostBreakdown] = None   # full cost-model terms

    def row(self) -> dict:
        d = dataclasses.asdict(self)
        d["degrees"] = dataclasses.asdict(self.degrees)
        d.pop("stage_layers")
        d.pop("breakdown")
        return d

    @classmethod
    def from_degrees(cls, cfg: ModelConfig, shape: ShapeConfig,
                     deg: Degrees, hw: Hardware = V5E, *,
                     method: str = "manual") -> "Plan":
        """Wrap hand-picked degrees (paper tables, ablations) as a Plan so
        they share summary/materialize/row with searched plans."""
        cb = estimate(cfg, shape, deg, hw)
        return cls(degrees=deg, cost=cb.step_time, mfu=cb.mfu, fits=cb.fits,
                   evaluations=1, method=method, breakdown=cb)

    def summary(self, *, compact: bool = False) -> str:
        """Canonical pretty-printer (replaces the per-caller hand
        formatting in launch/train.py and the examples)."""
        d = self.degrees
        desc = (f"dp{d.dp} tp{d.tp} pp{d.pp} m{d.microbatches}"
                f"{' sp' if d.seq_parallel else ''}"
                f"{' ep' + str(d.ep) if d.ep > 1 else ''}")
        if compact:
            return desc
        return (f"plan[{self.method}] {desc} -> est {self.cost:.3f}s/step, "
                f"MFU {self.mfu:.1%}, fits={self.fits} "
                f"({self.evaluations} evals)")

    def materialize(self, devices: Union[None, int, Sequence] = None,
                    **strategy_overrides):
        """Turn the plan into an executable ``(Strategy, Mesh)`` pair.

        ``devices``: None (all local jax devices), an int (the first N
        local devices), or an explicit device sequence. The degrees must
        exactly tile the device count (dp*pp*tp == len(devices)) — the
        legality check that keeps a searched plan from silently running on
        the wrong mesh. ``pp > 1`` yields a three-axis
        ("data", "pipe", "model") mesh for core/pipeline.py; otherwise the
        standard ("data", "model") layout.

        Extra keyword arguments override Strategy fields (e.g.
        ``dtype="float32"``, ``remat=False`` for CPU smoke runs).
        """
        import jax

        from repro.core.strategy import Strategy
        from repro.launch.mesh import make_mesh

        if devices is None:
            devs = list(jax.devices())
        elif isinstance(devices, int):
            devs = list(jax.devices())
            if devices > len(devs):
                raise ValueError(
                    f"plan asked for {devices} devices but only "
                    f"{len(devs)} are available")
            devs = devs[:devices]
        else:
            devs = list(devices)

        d = self.degrees
        need = d.dp * d.pp * d.tp
        if need != len(devs):
            raise ValueError(
                f"degrees dp{d.dp} x pp{d.pp} x tp{d.tp} = {need} chips "
                f"do not tile the {len(devs)} provided device(s); re-plan "
                f"with chips={len(devs)} or pass a matching device slice")
        if d.ep > 1 and d.tp % d.ep != 0 and d.ep % d.tp != 0:
            raise ValueError(
                f"expert-parallel degree ep{d.ep} must share the model "
                f"axis with tp{d.tp}")

        if d.pp > 1:
            mesh = make_mesh((d.dp, d.pp, d.tp), ("data", "pipe", "model"),
                             devices=devs)
        else:
            mesh = make_mesh((d.dp, d.tp), ("data", "model"), devices=devs)

        strategy = Strategy(
            name=f"plan/{self.method}",
            seq_parallel=d.seq_parallel,
            zero1=d.zero1,
            fsdp=d.fsdp,
            remat=d.remat,
            microbatches=d.microbatches,
        )
        if strategy_overrides:
            strategy = strategy.with_(**strategy_overrides)
        return strategy, mesh


def _divisors(n: int) -> List[int]:
    return [i for i in range(1, n + 1) if n % i == 0]


def legal_degrees(cfg: ModelConfig, shape: ShapeConfig,
                  chips: int) -> List[Degrees]:
    """Enumerate the strategy space (paper §4 'search-space' challenge:
    include every exploitable dimension, exclude illegal points)."""
    out = []
    heads = max(cfg.num_heads, cfg.ssm_heads, 1)
    for tp in _divisors(chips):
        if tp > 2 * heads:                      # no parallelism left to use
            continue
        for pp in _divisors(chips // tp):
            if pp > cfg.num_layers:
                continue
            dp = chips // (tp * pp)
            if shape.global_batch % dp != 0:
                continue
            micro_opts = sorted({1, min(shape.global_batch // dp, 4 * pp),
                                 shape.global_batch // dp})
            for m in micro_opts:
                if (shape.global_batch // dp) % m != 0:
                    continue
                for sp_flag in ((False, True) if tp > 1 else (False,)):
                    out.append(Degrees(
                        dp=dp, tp=tp, pp=pp,
                        ep=tp if cfg.is_moe else 1,
                        microbatches=m, seq_parallel=sp_flag,
                        remat=shape.kind == "train"))
    return out


def _evaluate(cfg, shape, deg, hw) -> Tuple[float, object]:
    cb = estimate(cfg, shape, deg, hw)
    penalty = 1.0 if cb.fits else 1e3           # infeasible = heavy penalty
    return cb.step_time * penalty, cb


def search_exhaustive(cfg, shape, chips: int, hw: Hardware = V5E) -> Plan:
    best, best_cb, n = None, None, 0
    for deg in legal_degrees(cfg, shape, chips):
        c, cb = _evaluate(cfg, shape, deg, hw)
        n += 1
        if best is None or c < best[0]:
            best = (c, deg)
            best_cb = cb
    return Plan(degrees=best[1], cost=best_cb.step_time, mfu=best_cb.mfu,
                fits=best_cb.fits, evaluations=n, method="exhaustive",
                breakdown=best_cb)


def search_dp(cfg, shape, chips: int, hw: Hardware = V5E) -> Plan:
    """Two-level: outer loop over (pp, tp); inner DP balances layers into
    stages by FLOPs from the operator graph (Alpa's hierarchy, simplified:
    our stages are homogeneous so the DP reduces to balanced cuts)."""
    graph = build_opgraph(cfg, shape.global_batch, shape.seq_len)
    best, best_cb, best_stages, n = None, None, None, 0
    for pp in _divisors(chips):
        if pp > cfg.num_layers:
            continue
        stages = graph.balanced_stages(pp) if pp > 1 else None
        for tp in _divisors(chips // pp):
            heads = max(cfg.num_heads, cfg.ssm_heads, 1)
            if tp > 2 * heads:
                continue
            dp = chips // (pp * tp)
            if shape.global_batch % dp != 0:
                continue
            m = min(shape.global_batch // dp, max(1, 4 * pp))
            while (shape.global_batch // dp) % m != 0:
                m -= 1
            deg = Degrees(dp=dp, tp=tp, pp=pp,
                          ep=tp if cfg.is_moe else 1, microbatches=m,
                          seq_parallel=tp > 1,
                          remat=shape.kind == "train")
            c, cb = _evaluate(cfg, shape, deg, hw)
            n += 1
            if best is None or c < best[0]:
                best, best_cb, best_stages = (c, deg), cb, stages
    return Plan(degrees=best[1], cost=best_cb.step_time, mfu=best_cb.mfu,
                fits=best_cb.fits, evaluations=n, method="dp",
                stage_layers=best_stages, breakdown=best_cb)


def search_mcmc(cfg, shape, chips: int, hw: Hardware = V5E, *,
                iters: int = 200, temp: float = 0.05,
                seed: int = 0) -> Plan:
    """FlexFlow-style MCMC: random legal moves, accept by Metropolis."""
    rng = random.Random(seed)
    space = legal_degrees(cfg, shape, chips)
    cur = rng.choice(space)
    cur_cost, cur_cb = _evaluate(cfg, shape, cur, hw)
    best, best_cb = (cur_cost, cur), cur_cb
    n = 1
    for _ in range(iters):
        cand = rng.choice(space)
        c, cb = _evaluate(cfg, shape, cand, hw)
        n += 1
        import math
        if c < cur_cost or rng.random() < math.exp(
                (cur_cost - c) / max(temp * cur_cost, 1e-12)):
            cur, cur_cost = cand, c
        if c < best[0]:
            best, best_cb = (c, cand), cb
    return Plan(degrees=best[1], cost=best_cb.step_time, mfu=best_cb.mfu,
                fits=best_cb.fits, evaluations=n, method="mcmc",
                breakdown=best_cb)


SEARCH_METHODS = {"exhaustive": search_exhaustive, "dp": search_dp,
                  "mcmc": search_mcmc}


def plan(cfg, shape, chips: int, *, method: str = "exhaustive",
         hw: Hardware = V5E) -> Plan:
    return SEARCH_METHODS[method](cfg, shape, chips, hw)
