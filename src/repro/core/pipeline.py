"""Inter-operator (pipeline) parallelism — paper §3.2/§4, Fig. 5.

TPU-idiomatic implementation: stages live on a dedicated "pipe" mesh axis;
activations move stage-to-stage with ``jax.lax.ppermute`` inside
``shard_map`` (the ICI-neighbour equivalent of PipeDream's P2P sends), and
micro-batches stream through a GPipe schedule expressed as a ``lax.scan``
over T = M + P - 1 ticks (Fig. 5c/5d exactly: the first P-1 and last P-1
ticks are the bubble).

The module also provides the schedule SIMULATOR used by
benchmarks/bench_pipeline_bubble.py to reproduce the paper's bubble-fraction
claims for GPipe and 1F1B without hardware.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import shard_map


# ------------------------------------------------------------ runtime (JAX)

def gpipe_spmd(stage_fn: Callable, microbatches, *, axis: str = "pipe"):
    """Run inside shard_map. ``stage_fn(x) -> y`` applies THIS device's
    stage; ``microbatches``: (M, mb, ...) replicated along ``axis``.

    Returns (M, mb, ...) final-stage outputs (replicated along ``axis``).
    Every stage computes every tick; ticks where a stage holds no valid
    micro-batch are the pipeline bubble (wasted FLOPs, exactly GPipe).
    """
    p = jax.lax.psum(1, axis)
    idx = jax.lax.axis_index(axis)
    m = microbatches.shape[0]
    t_total = m + p - 1

    def tick(carry, t):
        buf, outs = carry
        x0 = microbatches[jnp.clip(t, 0, m - 1)]
        x = jnp.where(idx == 0, x0, buf)
        y = stage_fn(x)
        out_i = jnp.clip(t - (p - 1), 0, m - 1)
        write = jnp.logical_and(idx == p - 1, t >= p - 1)
        upd = jax.lax.dynamic_update_index_in_dim(outs, y, out_i, 0)
        outs = jnp.where(write, upd, outs)
        # stage i -> i+1 ring (last stage's send is ignored by stage 0)
        buf = jax.lax.ppermute(y, axis,
                               [(i, (i + 1) % p) for i in range(p)])
        return (buf, outs), None

    buf0 = jnp.zeros_like(microbatches[0])
    outs0 = jnp.zeros_like(microbatches)
    (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(t_total))
    # replicate the last stage's outputs to every stage member
    outs = jax.lax.psum(jnp.where(idx == p - 1, outs, jnp.zeros_like(outs)),
                        axis)
    return outs


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   num_microbatches: int, axis: str = "pipe"):
    """High-level entry: ``stage_params`` leaves have leading dim P (one
    slice per stage, sharded over ``axis``); ``x``: (B, ...) global batch.

    stage_fn(params_slice, x_mb) -> y_mb with y_mb.shape == x_mb.shape.
    """
    b = x.shape[0]
    assert b % num_microbatches == 0
    micro = x.reshape(num_microbatches, b // num_microbatches, *x.shape[1:])

    pspec = P(axis)  # leading stage dim
    in_specs = (
        jax.tree.map(lambda _: pspec, stage_params),
        P(*([None] * micro.ndim)),
    )

    def spmd(params, mb):
        local = jax.tree.map(lambda a: a[0], params)  # strip stage dim
        return gpipe_spmd(lambda xx: stage_fn(local, xx), mb, axis=axis)

    out = shard_map(spmd, mesh=mesh, in_specs=in_specs,
                    out_specs=P(*([None] * micro.ndim)),
                    check_vma=False)(stage_params, micro)
    return out.reshape(b, *x.shape[1:])


# ------------------------------------------------------- schedule simulator

def simulate_schedule(num_stages: int, num_micro: int, *,
                      schedule: str = "gpipe",
                      fwd_time: float = 1.0,
                      bwd_time: float = 2.0) -> dict:
    """Tick-level simulation of GPipe vs 1F1B (Fig. 5c/5d + PipeDream [20]).

    Returns total time, ideal time, bubble fraction, and peak in-flight
    micro-batches per stage (the activation-memory driver [14]).
    """
    p, m = num_stages, num_micro
    if schedule == "gpipe":
        total = (m + p - 1) * fwd_time + (m + p - 1) * bwd_time
        ideal = m * (fwd_time + bwd_time)
        in_flight = min(m, p) if m else 0
        in_flight = m  # GPipe stores all micro-batch activations
    elif schedule == "1f1b":
        # warmup p-1 fwd, steady 1F1B, drain p-1 bwd
        total = (p - 1) * fwd_time + m * (fwd_time + bwd_time) \
            + (p - 1) * bwd_time
        ideal = m * (fwd_time + bwd_time)
        in_flight = min(m, p)
    else:
        raise ValueError(schedule)
    bubble = 1.0 - ideal / total
    # closed-form check from the paper: (p-1)/(m+p-1) for equal fwd/bwd split
    closed_form = (p - 1) / (m + p - 1)
    return {"schedule": schedule, "stages": p, "microbatches": m,
            "total_time": total, "ideal_time": ideal,
            "bubble_fraction": bubble, "closed_form_gpipe": closed_form,
            "peak_inflight_microbatches": in_flight}
