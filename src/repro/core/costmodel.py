"""Analytical cost model — the survey's §4 "strategy evaluation" problem.

Given (config, shape, parallel degrees, hardware), estimate the three
roofline terms + pipeline bubble + activation memory per device. This is the
evaluator the planner searches over (Alpa/TensorOpt use
profiling-calibrated models; ours is symbolic like Wang et al.'s double
recursive — paper Table 3 — but calibrated against the dry-run HLO).

Implements the survey's quantitative claims directly:
  * Megatron TP communication: 2 all-reduces per layer per microbatch fwd
    (one after attention out-proj, one after MLP row-matmul), 2 more in bwd
    [28, §5.1].
  * Korthikanti activation memory per layer:
        no SP :  s·b·h(10 + 24/t + 5·a·s/(h·t))
        SP    :  s·b·h/t · (34 + 5·a·s/h)            [14, §5.1]
  * GPipe bubble fraction: (p-1)/(m+p-1)             [11, Fig. 5]
  * DP gradient all-reduce: 2·(d-1)/d · P_local bytes [20/24-style]
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.opgraph import build_opgraph


@dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12        # bf16/chip (TPU v5e)
    hbm_bw: float = 819e9
    ici_bw: float = 50e9              # per link
    ici_links: int = 2
    dcn_bw: float = 25e9
    hbm_bytes: float = 16e9
    node_size: int = 0           # fast-interconnect island (0 = whole pod,
                                 # TPU ICI); GPUs: NVLink node of 8


V5E = Hardware()
A100 = Hardware(peak_flops=312e12, hbm_bw=2039e9, ici_bw=300e9, ici_links=1,
                dcn_bw=12.5e9, hbm_bytes=80e9, node_size=8)
V100 = Hardware(peak_flops=125e12, hbm_bw=900e9, ici_bw=150e9, ici_links=1,
                dcn_bw=12.5e9, hbm_bytes=32e9, node_size=8)
TPU_V3 = Hardware(peak_flops=123e12, hbm_bw=900e9, ici_bw=70e9, ici_links=2,
                  dcn_bw=25e9, hbm_bytes=32e9)
TPU_V4 = Hardware(peak_flops=275e12, hbm_bw=1200e9, ici_bw=50e9, ici_links=3,
                  dcn_bw=25e9, hbm_bytes=32e9)


@dataclass(frozen=True)
class Degrees:
    dp: int = 1
    tp: int = 1
    pp: int = 1
    ep: int = 1        # expert parallel (shares the tp axis unless noted)
    microbatches: int = 1
    seq_parallel: bool = False
    remat: bool = True
    zero1: bool = True
    fsdp: bool = False

    @property
    def chips(self) -> int:
        return self.dp * self.tp * self.pp


def _allreduce_bytes(nbytes: float, n: int) -> float:
    """Ring all-reduce: 2 (n-1)/n per-device traffic."""
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * nbytes


def _allgather_bytes(nbytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) / n * nbytes


@dataclass
class CostBreakdown:
    t_compute: float
    t_memory: float
    t_collective: float
    bubble_fraction: float
    param_bytes_dev: float
    opt_bytes_dev: float
    act_bytes_dev: float
    fits: bool
    step_time: float
    mfu: float

    def row(self) -> dict:
        return dataclasses.asdict(self)


def activation_bytes_per_layer(cfg: ModelConfig, b_micro: int, seq: int,
                               tp: int, seq_parallel: bool) -> float:
    """Korthikanti et al. per-layer activation memory [14]."""
    s, b, h, a = seq, b_micro, cfg.d_model, max(cfg.num_heads, 1)
    if seq_parallel:
        return s * b * h * (34 + 5 * a * s / h) / tp
    return s * b * h * (10 + 24 / tp + 5 * a * s / (h * tp))


def estimate(cfg: ModelConfig, shape: ShapeConfig, deg: Degrees,
             hw: Hardware = V5E, *, dcn_dp: int = 1) -> CostBreakdown:
    """Estimate one training (or prefill) step under ``deg``."""
    tokens = shape.global_batch * shape.seq_len
    graph = build_opgraph(cfg, shape.global_batch, shape.seq_len)
    fwd_flops = graph.total_flops()
    train = shape.kind == "train"
    mult = 3.0 if train else 1.0                  # bwd = 2x fwd
    if train and deg.remat:
        mult += 1.0                               # recompute fwd
    total_flops = fwd_flops * mult
    t_compute = total_flops / (deg.chips * hw.peak_flops)

    # ---- memory traffic: params read once per microbatch + activations
    param_bytes = graph.total_param_bytes()
    act_bytes = sum(n.act_bytes for n in graph.nodes) * (2 if train else 1)
    t_memory = (param_bytes * deg.microbatches / (deg.tp * deg.pp)
                + act_bytes / deg.chips) * mult / hw.hbm_bw

    # ---- collectives (per device)
    b_micro = shape.global_batch // (deg.dp * deg.microbatches) or 1
    sbh = shape.seq_len * b_micro * cfg.d_model * 2          # bf16 bytes
    n_layers = cfg.num_layers / deg.pp
    coll = 0.0
    tp_bw = hw.ici_bw * hw.ici_links
    if hw.node_size and deg.tp > hw.node_size:
        # intra-operator parallelism spilling past the fast-interconnect
        # island pays the slow link (the paper's takeaway #1 / §6)
        tp_bw = hw.dcn_bw
    coll_tp = 0.0
    if deg.tp > 1:
        per_layer_ar = 2 * (2 if train else 1)               # fwd(+bwd)
        vol = _allreduce_bytes(sbh, deg.tp)
        if deg.seq_parallel:
            # RS + AG replaces each AR at the same ring volume
            vol = _allreduce_bytes(sbh, deg.tp)
        coll_tp += n_layers * per_layer_ar * vol * deg.microbatches
    if cfg.is_moe and deg.ep > 1:
        # 2 all-to-alls fwd (+2 bwd): k/E of tokens leave the device
        a2a = sbh * cfg.experts_per_token / deg.ep
        coll += n_layers * (4 if train else 2) * a2a * deg.microbatches
    if train and deg.dp > 1:
        coll += _allreduce_bytes(param_bytes * 2 / (deg.tp * deg.pp), deg.dp)
    if deg.fsdp:
        coll += _allgather_bytes(param_bytes * 2 / (deg.tp * deg.pp),
                                 deg.dp) * deg.microbatches * mult / 3
    if deg.pp > 1:
        coll += 2 * sbh * deg.microbatches * (2 if train else 1)
    t_collective = coll / (hw.ici_bw * hw.ici_links) + coll_tp / tp_bw
    if dcn_dp > 1 and train:
        t_collective += _allreduce_bytes(
            param_bytes * 2 / (deg.tp * deg.pp), dcn_dp) / hw.dcn_bw

    # ---- pipeline bubble [11]
    m, p = deg.microbatches, deg.pp
    bubble = (p - 1) / (m + p - 1) if p > 1 else 0.0

    # ---- per-device memory
    param_dev = param_bytes * 2 / (deg.tp * deg.pp * (deg.dp if deg.fsdp
                                                      else 1))
    if not train:
        opt_dev = 0.0
    else:
        per_param = {"adamw": 16.0, "adafactor": 4.1}.get("adamw")
        opt_dev = (param_bytes * per_param / 2
                   / (deg.tp * deg.pp * (deg.dp if deg.zero1 else 1)))
    if train:
        if deg.remat:
            act_dev = (shape.seq_len * b_micro * cfg.d_model * 2
                       * n_layers / deg.tp)
        else:
            act_dev = (activation_bytes_per_layer(
                cfg, b_micro, shape.seq_len, deg.tp, deg.seq_parallel)
                * n_layers)
    else:
        act_dev = act_bytes / deg.chips
    fits = param_dev + opt_dev + act_dev < hw.hbm_bytes

    step = max(t_compute, t_memory, t_collective) / max(1e-9, (1 - bubble))
    model_flops = 6.0 * cfg.active_param_count() * tokens if train else \
        2.0 * cfg.active_param_count() * tokens
    mfu = model_flops / (deg.chips * hw.peak_flops * step) if step else 0.0
    return CostBreakdown(
        t_compute=t_compute, t_memory=t_memory, t_collective=t_collective,
        bubble_fraction=bubble, param_bytes_dev=param_dev,
        opt_bytes_dev=opt_dev, act_bytes_dev=act_dev, fits=fits,
        step_time=step, mfu=mfu)
