"""Logical-axis sharding: the bridge between model code and the mesh.

Model code annotates activations with LOGICAL axis names ("batch", "heads",
"d_ff", "experts", ...). A Strategy installs a rules table mapping logical
names to mesh axes (or None). ``constrain`` applies
``jax.lax.with_sharding_constraint`` only when rules + a mesh are active, so
the same model code runs unsharded on one CPU device and sharded under pjit
on the production mesh. This mirrors GSPMD's sharding-annotation programming
model, which is itself one of the frameworks surveyed by the paper (Table 3).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, tuple]

_state = threading.local()


def _rules() -> Optional[Mapping[str, MeshAxes]]:
    return getattr(_state, "rules", None)


def _mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextmanager
def sharding_rules(mesh: Mesh, rules: Mapping[str, MeshAxes]):
    """Activate a logical->mesh axis mapping (and the mesh) for model code."""
    old = (_rules(), _mesh())
    _state.rules, _state.mesh = dict(rules), mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = old


def logical_to_spec(axes: Sequence[Optional[str]],
                    rules: Optional[Mapping[str, MeshAxes]] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    rules = rules if rules is not None else (_rules() or {})
    return P(*[rules.get(a) if a is not None else None for a in axes])


def constrain(x: Any, *axes: Optional[str]):
    """Sharding-constrain ``x`` by logical axes; no-op without active rules."""
    rules, mesh = _rules(), _mesh()
    if rules is None or mesh is None:
        return x
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs logical axes {axes}")
    spec = logical_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
