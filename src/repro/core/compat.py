"""Version tolerance for the jax API surface this repo leans on.

The codebase targets current jax (``jax.shard_map``, ``check_vma``,
``AxisType``) but must also run on 0.4.x images where shard_map still
lives under ``jax.experimental`` and the replication check is spelled
``check_rep``. Mesh construction compat lives in ``repro.launch.mesh``.
"""
from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                          # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """jax.shard_map across versions (check_vma <-> check_rep rename)."""
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
