"""Parallelisation Strategy — the paper's taxonomy as a first-class object.

A Strategy fixes, for a given mesh, how each *parallelisable dimension*
(paper §3.1.2) maps onto mesh axes:

  dp  — data parallelism                 ("data" axis, x "pod" axis)
  tp  — intra-operator / tensor          ("model" axis; Megatron §5.1)
  ep  — intra-operator over experts      ("model" axis; MoE archs)
  pp  — inter-operator / pipeline        (dedicated "pipe" axis; core/pipeline.py)
  sp  — sequence parallelism             (Korthikanti; seq dim -> "model")

plus the execution knobs the survey's case-studies tune: microbatch count
(GPipe Fig. 5d), remat (checkpointing §3.1.3), ZeRO-1 optimizer-state
sharding (DeepSpeed, used by MT-NLG [29]).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from jax.sharding import Mesh


@dataclass(frozen=True)
class Strategy:
    name: str = "megatron"
    # parallel degrees are implied by the mesh axes; these flags pick HOW
    # the logical dims map onto them.
    seq_parallel: bool = False       # Korthikanti SP (beyond-baseline)
    expert_parallel: bool = True     # MoE experts on "model" (vs TP-in-expert)
    zero1: bool = True               # shard optimizer states over "data"
    fsdp: bool = False               # ZeRO-3: shard PARAMS over "data" too
    optimizer: str = "adamw"         # adamw | adafactor
    grad_accum_dtype: str = "float32"  # bfloat16 halves the accumulator
    remat: bool = True               # full activation checkpointing per layer
    microbatches: int = 1            # grad-accumulation steps (GPipe Fig. 5d)
    attn_impl: str = "auto"          # masked | triangle | full | auto
    dtype: str = "bfloat16"

    def rules(self, mesh: Mesh) -> dict:
        """Logical-axis -> mesh-axis table for core/pspec.constrain."""
        axes = mesh.axis_names
        batch = tuple(a for a in ("pod", "data") if a in axes) or None
        if batch and len(batch) == 1:
            batch = batch[0]
        r = {
            "batch": batch,
            "seq": "model" if self.seq_parallel else None,
            "heads": "model",
            "kv_heads": "model",
            "d_ff": "model",
            "vocab": "model",
            "ssm_inner": "model",
            "ssm_heads": "model",
            "experts": "model" if self.expert_parallel else None,
            "d_ff_moe": None if self.expert_parallel else "model",
            # expert-capacity dim of the (E, C, d) dispatch buffer: shard
            # over "data" so DP replicas split expert work instead of each
            # computing ALL experts' global capacity (16x compute waste
            # found in the baseline dry-run — EXPERIMENTS.md §Perf).
            "moe_cap": batch,
            # the dispatch scatter / combine gather index dim 0 only, so they
            # partition cleanly along d -> shard d over "model" just for
            # those two ops (16x traffic cut on the 1T MoE — §Perf).
            "moe_dispatch_d": "model",
            "d_model": None,
        }
        return r

    def with_(self, **kw) -> "Strategy":
        return dataclasses.replace(self, **kw)


MEGATRON_BASELINE = Strategy(name="megatron", seq_parallel=False)
# beyond-paper optimized default: +sequence parallelism
MEGATRON_SP = Strategy(name="megatron+sp", seq_parallel=True)
