"""Operator-graph IR — the paper's §3.1.2 formal framework, executable.

O = (V, E): nodes are operators (with FLOPs, param bytes, activation bytes)
or tensors; edges carry tensors between operators. We build the graph
analytically from a ModelConfig — it is the substrate for:

  * the cost model (core/costmodel.py) — per-node compute/memory terms,
  * the planner's inter-operator (pipeline) partitioning — balanced
    stage cuts over node FLOPs (RaNNC/FTPipe-style, paper Table 3),
  * the parallelisable-dimension bookkeeping (paper §3.1: sample /
    attribute / parameter / operator — FlexFlow's SOAP dims).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig


@dataclass
class OpNode:
    name: str
    kind: str                      # matmul | attention | norm | embed | ...
    flops: float                   # forward FLOPs for the whole batch
    param_bytes: float
    act_bytes: float               # output activation bytes
    # SOAP-style parallelisable dims: logical-dim -> max degree
    parallel_dims: Dict[str, int] = field(default_factory=dict)
    layer: Optional[int] = None    # layer index (None = trunk-level)


@dataclass
class OpGraph:
    nodes: List[OpNode]
    edges: List[Tuple[str, str]]
    cfg: ModelConfig

    def total_flops(self) -> float:
        return sum(n.flops for n in self.nodes)

    def total_param_bytes(self) -> float:
        return sum(n.param_bytes for n in self.nodes)

    def layer_nodes(self) -> Dict[int, List[OpNode]]:
        out: Dict[int, List[OpNode]] = {}
        for n in self.nodes:
            if n.layer is not None:
                out.setdefault(n.layer, []).append(n)
        return out

    def balanced_stages(self, num_stages: int) -> List[List[int]]:
        """Greedy balanced partition of layers into pipeline stages by
        FLOPs (the inter-operator search sub-problem, paper §4)."""
        per_layer = {k: sum(n.flops for n in v)
                     for k, v in self.layer_nodes().items()}
        layers = sorted(per_layer)
        total = sum(per_layer.values())
        target = total / num_stages
        stages, cur, acc = [], [], 0.0
        for li in layers:
            cur.append(li)
            acc += per_layer[li]
            if acc >= target * (len(stages) + 1) and len(stages) < num_stages - 1:
                stages.append(cur)
                cur = []
        stages.append(cur)
        while len(stages) < num_stages:
            stages.append([])
        return stages


def _bytes(n: float, dtype_bytes: int = 2) -> float:
    return n * dtype_bytes


def build_opgraph(cfg: ModelConfig, batch: int, seq: int) -> OpGraph:
    """Analytical operator graph for one forward pass of ``batch x seq``."""
    b, s, d, f, v = batch, seq, cfg.d_model, cfg.d_ff, cfg.vocab_size
    t = b * s
    nodes: List[OpNode] = []
    edges: List[Tuple[str, str]] = []
    prev = "embed"
    nodes.append(OpNode("embed", "embed", 0.0, _bytes(v * d),
                        _bytes(t * d), {"sample": b, "vocab": v}))

    def attn_nodes(li: int, prefix: str, kv_len: int, heads: int,
                   kv_heads: int):
        hd = cfg.head_dim
        qkv_flops = 2 * t * d * (heads * hd + 2 * kv_heads * hd)
        if cfg.sliding_window:
            kv_eff = min(kv_len, cfg.sliding_window)
        else:
            kv_eff = kv_len
        att_flops = 2 * 2 * t * kv_eff * heads * hd  # QK^T + PV (causal ~ /2)
        out_flops = 2 * t * heads * hd * d
        ns = [
            OpNode(f"{prefix}{li}.qkv", "matmul", qkv_flops,
                   _bytes(d * (heads + 2 * kv_heads) * hd),
                   _bytes(t * (heads + 2 * kv_heads) * hd),
                   {"parameter": heads, "sample": b}, li),
            OpNode(f"{prefix}{li}.attn", "attention", att_flops, 0.0,
                   _bytes(t * heads * hd),
                   {"attribute": heads, "sample": b}, li),
            OpNode(f"{prefix}{li}.out", "matmul", out_flops,
                   _bytes(heads * hd * d), _bytes(t * d),
                   {"parameter": heads, "sample": b}, li),
        ]
        return ns

    def mlp_nodes(li: int, gated: bool = True):
        n_mats = 3 if gated else 2
        return [OpNode(f"mlp{li}", "matmul", 2 * t * d * f * n_mats,
                       _bytes(n_mats * d * f), _bytes(t * f),
                       {"parameter": f, "sample": b}, li)]

    def moe_nodes(li: int):
        k, e = cfg.experts_per_token, cfg.num_experts
        return [
            OpNode(f"router{li}", "matmul", 2 * t * d * e, _bytes(d * e, 4),
                   _bytes(t * e, 4), {"sample": b}, li),
            OpNode(f"experts{li}", "moe", 2 * t * k * d * cfg.d_ff * 3,
                   _bytes(e * 3 * d * cfg.d_ff), _bytes(t * k * cfg.d_ff),
                   {"parameter": e, "sample": b}, li),
        ]

    def ssm_nodes(li: int):
        h, p_, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        din = h * p_
        q = cfg.ssm_chunk
        proj = 2 * t * d * (2 * din + 2 * n + h)
        intra = 2 * t * q * (n + h * p_)          # C.B^T + att@x per chunk
        inter = 2 * t * n * h * p_                # state update + C.h
        outp = 2 * t * din * d
        return [
            OpNode(f"ssm{li}.proj", "matmul", proj,
                   _bytes(d * (2 * din + 2 * n + h)), _bytes(t * 2 * din),
                   {"parameter": h, "sample": b}, li),
            OpNode(f"ssm{li}.ssd", "ssd", intra + inter, 0.0,
                   _bytes(t * din), {"attribute": h, "sample": b}, li),
            OpNode(f"ssm{li}.out", "matmul", outp, _bytes(din * d),
                   _bytes(t * d), {"parameter": h, "sample": b}, li),
        ]

    li = 0
    if cfg.arch_type in ("dense", "moe", "vlm"):
        for li in range(cfg.num_layers):
            is_cross = (cfg.cross_attn_every > 0 and
                        (li + 1) % (cfg.cross_attn_every + 1) == 0)
            kv_len = cfg.num_image_tokens if is_cross else s
            ns = attn_nodes(li, "xattn" if is_cross else "attn", kv_len,
                            cfg.num_heads, cfg.num_kv_heads)
            ns += moe_nodes(li) if (cfg.is_moe and not is_cross) \
                else mlp_nodes(li)
            nodes += ns
    elif cfg.arch_type == "ssm":
        for li in range(cfg.num_layers):
            nodes += ssm_nodes(li)
    elif cfg.arch_type == "hybrid":
        g = cfg.num_layers // cfg.hybrid_attn_every
        for li in range(cfg.num_layers):
            nodes += ssm_nodes(li)
            if (li + 1) % cfg.hybrid_attn_every == 0:
                nodes += attn_nodes(li, "shared_attn", s, cfg.num_heads,
                                    cfg.num_kv_heads)
                nodes += mlp_nodes(li)
    elif cfg.arch_type == "audio":
        for li in range(cfg.encoder_layers):
            nodes += attn_nodes(li, "enc_attn", cfg.encoder_ctx,
                                cfg.num_heads, cfg.num_kv_heads)
            nodes += mlp_nodes(li, gated=False)
        for lj in range(cfg.num_layers):
            li = cfg.encoder_layers + lj
            nodes += attn_nodes(li, "dec_attn", s, cfg.num_heads,
                                cfg.num_kv_heads)
            nodes += attn_nodes(li, "dec_xattn", cfg.encoder_ctx,
                                cfg.num_heads, cfg.num_kv_heads)
            nodes += mlp_nodes(li, gated=False)

    nodes.append(OpNode("lm_head", "matmul", 2 * t * d * v, _bytes(d * v),
                        _bytes(t * v), {"parameter": v, "sample": b},
                        None))
    names = [n.name for n in nodes]
    edges = list(zip(names[:-1], names[1:]))
    return OpGraph(nodes, edges, cfg)
