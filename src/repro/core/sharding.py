"""Parameter and state sharding rules (Megatron §5.1, GSPMD-style).

``param_logical(path, leaf)`` maps every parameter to logical axes by its
name; a Strategy's rules table then yields PartitionSpecs. The rules encode
the paper's §5.1 scheme exactly:

  * MLP:  A (w_gate/w_up) split over COLUMNS (d_ff), B (w_down) over ROWS
    (d_ff)  =>  GeLU local, ONE forward all-reduce (validated by
    tests/test_tp_collectives.py against the lowered HLO).
  * Attention: wq/wk/wv column-split by head, wo row-split.
  * Embedding / LM head: vocab-split (Megatron vocab-parallel).
  * MoE: expert axis split (expert parallelism) — the survey's MoE-era
    all-to-all pattern; or TP-in-expert when Strategy.expert_parallel=False.
  * Mamba2: in_proj column-split (whole SSD heads per device, local scan),
    out_proj row-split — the paper's insight transferred to SSM blocks
    (DESIGN.md §4.1).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.pspec import logical_to_spec
from repro.core.strategy import Strategy

# leaf name -> logical axes of the TRAILING dims (leading dims — layer
# stacking from scan — are unsharded).
_TRAILING = {
    # attention (column for qkv, row for o)
    "wq": ("d_model", "heads"),
    "wk": ("d_model", "kv_heads"),
    "wv": ("d_model", "kv_heads"),
    "wo": ("heads", "d_model"),
    # dense MLP (column, column, row)
    "w_gate": ("d_model", "d_ff"),
    "w_up": ("d_model", "d_ff"),
    "w_down": ("d_ff", "d_model"),
    # embeddings (vocab-parallel)
    "embed": ("vocab", "d_model"),
    "tok_embed": ("vocab", "d_model"),
    "lm_head": ("d_model", "vocab"),
    # MoE
    "router": ("d_model", None),
    # Mamba2
    "in_proj": ("d_model", "ssm_inner"),
    "out_proj": ("ssm_inner", "d_model"),
    "conv_w": (None, "ssm_inner"),
    "conv_b": ("ssm_inner",),
    "dt_bias": ("ssm_heads",),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "gn_scale": ("ssm_inner",),
}

# MoE expert tensors, keyed by (parent, leaf)
_MOE_TRAILING = {
    "w_gate": ("experts", "d_model", "d_ff_moe"),
    "w_up": ("experts", "d_model", "d_ff_moe"),
    "w_down": ("experts", "d_ff_moe", "d_model"),
}

_REPLICATED_NAMES = {"ln1", "ln2", "lnx", "norm", "final_norm", "enc_norm",
                     "q_norm", "k_norm", "gate_attn", "gate_mlp"}


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def param_logical(path, leaf) -> Tuple[Optional[str], ...]:
    """Logical axes for one parameter leaf (padded with None on the left
    for scan-stacked leading dims)."""
    names = _path_names(path)
    leaf_name = names[-1]
    if leaf_name in _REPLICATED_NAMES:
        return (None,) * leaf.ndim
    if leaf_name in _MOE_TRAILING and "moe" in names:
        trailing = _MOE_TRAILING[leaf_name]
    elif leaf_name in _TRAILING:
        trailing = _TRAILING[leaf_name]
    else:
        return (None,) * leaf.ndim
    pad = leaf.ndim - len(trailing)
    assert pad >= 0, (names, leaf.shape, trailing)
    return (None,) * pad + tuple(trailing)


def _divisible(shape, spec: P, mesh: Mesh) -> P:
    """Drop shardings that don't divide (GSPMD pads, but for PARAMETERS we
    prefer exact shardings; activations stay padded-sharded)."""
    new = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            new.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        new.append(ax if dim % size == 0 else None)
    return P(*new)


def param_pspecs(params: Any, strategy: Strategy, mesh: Mesh):
    """Pytree of PartitionSpec matching ``params``."""
    rules = strategy.rules(mesh)

    def one(path, leaf):
        spec = logical_to_spec(param_logical(path, leaf), rules)
        spec = _divisible(leaf.shape, spec, mesh)
        if strategy.fsdp:
            # ZeRO-3/FSDP: additionally shard over "data" on the first free
            # divisible dim; GSPMD inserts the per-use all-gather.
            spec = zero1_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, strategy: Strategy, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, strategy, mesh))


def zero1_spec(spec: P, shape, mesh: Mesh, axis: str = "data") -> P:
    """ZeRO-1: additionally shard an optimizer-state tensor over ``axis``
    on the first unsharded, divisible dim (DeepSpeed-style, used by
    MT-NLG [29])."""
    if axis not in mesh.axis_names:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    used = set()
    for ax in entries:
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            if a is not None:
                used.add(a)
    if axis in used:            # already sharded over it (e.g. FSDP+ZeRO-1)
        return P(*entries)
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None and dim % mesh.shape[axis] == 0:
            entries[i] = axis
            return P(*entries)
    return spec


def _state_leaf_spec(state_leaf, param_leaf, spec: P, mesh: Mesh,
                     zero1: bool) -> P:
    """Spec for an optimizer-state leaf derived from its parameter's spec.
    Handles full-shape (m/v/master), row-factored (vr = shape[:-1]) and
    col-factored (vc = shape[:-2] + shape[-1:]) Adafactor states."""
    pshape, sshape = param_leaf.shape, state_leaf.shape
    entries = tuple(spec) + (None,) * (len(pshape) - len(spec))
    if sshape == pshape:
        out = P(*entries)
    elif len(pshape) >= 2 and sshape == pshape[:-1]:
        out = P(*entries[:-1])
    elif len(pshape) >= 2 and sshape == pshape[:-2] + pshape[-1:]:
        out = P(*(entries[:-2] + entries[-1:]))
    elif sshape == ():
        return P()
    else:
        out = P(*([None] * len(sshape)))
    if zero1:
        out = zero1_spec(out, sshape, mesh)
    return _divisible(sshape, out, mesh)


def opt_state_pspecs(opt_state, params, strategy: Strategy, mesh: Mesh):
    """Specs matching the optimizer-state pytree (AdamW m/v/master or
    Adafactor vr/vc), ZeRO-1-sharded over "data" when enabled."""
    pspecs = param_pspecs(params, strategy, mesh)
    out = {}
    for k, sub in opt_state.items():
        if k == "step":
            out[k] = P()
        else:
            out[k] = jax.tree.map(
                lambda s, p, sp: _state_leaf_spec(s, p, sp, mesh,
                                                  strategy.zero1),
                sub, params, pspecs)
    return out


# ---------------------------------------------------------------- caches

def cache_pspecs(cache: Any, strategy: Strategy, mesh: Mesh, batch: int):
    """KV / SSM cache specs: batch over data (when divisible), heads over
    model. Cache layouts: kv k/v (L,B,W,Hkv,D); ssm state (L,B,H,P,N);
    conv (L,B,W,C); xkv like kv.

    A cache carrying a ``"ptab"`` page table (the serve engine's paged
    layout) holds its decoder KV as one flat POOL
    (L, n_pages, page_size, Hkv, D) shared by every slot instead of
    per-slot rows: the pool is head-sharded over "model" (each device
    keeps Hkv/tp heads of EVERY page — intra-operator TP for serving)
    and never batch-sharded (pages have no batch dim; data-parallel
    serving replicates whole engines, serve/parallel.py). The page table
    itself and any dense leaves riding along (the enc-dec cross-KV
    ``xkv``, SSM states) keep their usual specs."""
    rules = strategy.rules(mesh)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    bspec = rules["batch"] if batch % dp == 0 else None

    model_size = mesh.shape.get("model", 1)
    paged = isinstance(cache, dict) and "ptab" in cache

    def one(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0 or names[-1] == "pos":
            return P()
        if paged and names[0] == "kv" and names[-1] in ("k", "v"):
            # the flat page pool: shard the kv-head axis over "model"
            # (fall back to replicated when GQA heads don't divide — the
            # page axis must stay whole, a gather index crosses it)
            spec = P(None, None, None,
                     rules["kv_heads"] if leaf.shape[3] % model_size == 0
                     else None, None)
            return _divisible(leaf.shape, spec, mesh)
        if names[-1] in ("k", "v"):
            # Prefer KV-head sharding (Megatron); when GQA kv_heads don't
            # divide the model axis, shard the cache SEQUENCE dim instead
            # (context-parallel decode) so the cache still fits.
            if leaf.shape[3] % model_size == 0:
                spec = P(None, bspec, None, rules["kv_heads"], None)
            elif leaf.shape[2] % model_size == 0:
                spec = P(None, bspec, "model", None, None)
            else:
                spec = P(None, bspec, None, None, None)
        elif names[-1] == "state":
            spec = P(None, bspec, rules["ssm_heads"], None, None)
        elif names[-1] == "conv":
            spec = P(None, bspec, None, rules["ssm_inner"])
        else:
            spec = P(*([None] * leaf.ndim))
        return _divisible(leaf.shape, spec, mesh)

    return jax.tree_util.tree_map_with_path(one, cache)
