"""Checkpointing: pytree -> sharded .npz files + JSON manifest.

Layout:  <dir>/step_<n>/arrays.npz  (flattened key-path -> array)
         <dir>/step_<n>/manifest.json (treedef repr, shapes, dtypes, step)

Arrays are gathered to host (fine for the CPU/example scale; a production
TPU deployment would swap the .npz writer for per-shard tensorstore writes
— the manifest format already records per-leaf metadata to allow that).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory, step: int, tree: Any) -> Path:
    d = Path(directory) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(d / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
    }
    (d / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return d


def latest_step(directory) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.match(r"step_(\d+)$", p.name))]
    return max(steps) if steps else None


def load_checkpoint(directory, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    d = Path(directory) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
