"""Data pipeline: deterministic synthetic LM data + memmapped token files,
sharded per data-parallel rank.

Synthetic corpus: a mixture of (a) Zipf-distributed unigrams and (b) short
arithmetic-progression motifs — enough structure that a ~100M model's loss
drops visibly within a few hundred steps (examples/train_lm.py), while
requiring no external downloads (offline box).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: Optional[str] = None          # memmapped .bin (uint32) corpus
    kind: str = "synthetic"             # synthetic | file


class TokenDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.kind == "file":
            assert cfg.path is not None
            self.tokens = np.memmap(cfg.path, dtype=np.uint32, mode="r")
        else:
            self.tokens = None
        self._rng = np.random.default_rng(cfg.seed)
        # Zipf weights over the vocab (clipped for numerical sanity)
        ranks = np.arange(1, cfg.vocab_size + 1)
        w = 1.0 / ranks**1.1
        self._zipf = w / w.sum()

    def _synthetic_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        b, s = cfg.global_batch, cfg.seq_len
        base = rng.choice(cfg.vocab_size, size=(b, s), p=self._zipf)
        # motif: arithmetic runs the model can learn to continue
        starts = rng.integers(0, cfg.vocab_size // 2, size=(b,))
        strides = rng.integers(1, 7, size=(b,))
        runlen = min(s, 32)
        pos = rng.integers(0, s - runlen + 1, size=(b,))
        for i in range(b):
            run = (starts[i] + strides[i] * np.arange(runlen)) % cfg.vocab_size
            base[i, pos[i]:pos[i] + runlen] = run
        return base.astype(np.int32)

    def _file_batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        n = len(self.tokens) - cfg.seq_len - 1
        rng = np.random.default_rng(cfg.seed * 7_000_003 + step)
        idx = rng.integers(0, n, size=(cfg.global_batch,))
        return np.stack([self.tokens[i:i + cfg.seq_len] for i in idx]
                        ).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = (self._file_batch(step) if self.cfg.kind == "file"
                else self._synthetic_batch(step))
        labels = np.concatenate(
            [toks[:, 1:], np.full_like(toks[:, :1], -1)], axis=1)
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def shard_batch(batch: Dict[str, np.ndarray], mesh, batch_sharding):
    """Place a host batch onto the mesh with the Strategy's shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), batch,
        {k: batch_sharding[k] for k in batch})
