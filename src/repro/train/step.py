"""Training-step builder: forward+backward+optimizer under a Strategy.

``make_train_step(cfg, strategy)`` returns a pure function
``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
suitable for ``jax.jit`` with the shardings from core/sharding.py. Grad
accumulation over ``strategy.microbatches`` runs as a ``lax.scan`` (fp32
accumulators), which is also what bounds activation memory for the big
dry-run shapes (paper Fig. 5d's micro-batching, applied to DP).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, get_optimizer)
from repro.train.losses import cross_entropy


def make_loss_fn(cfg, strategy: Strategy) -> Callable:
    model = get_model(cfg)

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch, cfg,
                                    remat=strategy.remat,
                                    attn_impl=strategy.attn_impl)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.concatenate(
                [batch["tokens"][:, 1:],
                 jnp.full_like(batch["tokens"][:, :1], -1)], axis=1)
        loss = cross_entropy(logits, labels)
        total = loss + cfg.router_aux_coef * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def init_opt_state(params, strategy: Strategy):
    init, _ = get_optimizer(strategy.optimizer)
    return init(params)


def make_train_step(cfg, strategy: Strategy, *, lr: float = 3e-4,
                    max_grad_norm: float = 1.0) -> Callable:
    loss_fn = make_loss_fn(cfg, strategy)
    _, opt_update = get_optimizer(strategy.optimizer)
    n_micro = strategy.microbatches

    def grads_of(params, batch):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics

    def train_step(params, opt_state, batch):
        if n_micro > 1:
            # split batch dim -> (n_micro, b/n_micro, ...) and accumulate
            acc_dt = jnp.dtype(strategy.grad_accum_dtype)

            def resh(x):
                b = x.shape[0]
                assert b % n_micro == 0, (b, n_micro)
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(resh, batch)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            met0 = {"loss": jnp.zeros((), jnp.float32),
                    "aux_loss": jnp.zeros((), jnp.float32)}

            def body(carry, mb):
                acc, met = carry
                g, m = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: a + (gi.astype(jnp.float32)
                                       / n_micro).astype(acc_dt),
                    acc, g)
                met = jax.tree.map(lambda a, b_: a + b_ / n_micro, met, m)
                return (acc, met), None

            (grads, metrics), _ = jax.lax.scan(body, (acc0, met0), micro)
        else:
            grads, metrics = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        params, opt_state = opt_update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, grad_norm=gnorm)
        return params, opt_state, metrics

    return train_step
