"""Loss functions (fp32 accumulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """logits (B,S,V), labels (B,S) int32. Mean over non-ignored tokens."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits,
                             jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def token_accuracy(logits, labels, *, ignore_id: int = -1):
    pred = jnp.argmax(logits, axis=-1)
    mask = (labels != ignore_id)
    return (jnp.where(mask, pred == labels, False).sum()
            / jnp.maximum(mask.sum(), 1))
