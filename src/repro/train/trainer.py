"""Trainer: the end-to-end driver tying data, strategy, sharding,
train_step, metrics and checkpointing together.

Preferred entrypoint: ``repro.api.Session.train(...)`` — the Session owns
param init / checkpoint restore and threads the same params into
``generate``/``serve``. Constructing a Trainer directly (launch/train.py
pre-redesign style) still works: with ``params=None`` it initialises its
own sharded params via ``init_sharded_params``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core import sharding as shd
from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.data.pipeline import DataConfig, TokenDataset
from repro.launch.specs import batch_shardings
from repro.models import get_model
from repro.train.step import init_opt_state, make_train_step


def init_sharded_params(cfg: ModelConfig, strategy: Strategy, mesh: Mesh,
                        *, seed: int = 0):
    """Initialise model params jit-sharded straight onto ``mesh`` per the
    strategy's rules (no host-side full copy). Used by Trainer and by
    repro.api.Session so every execution mode shares one init path."""
    model = get_model(cfg)
    with sharding_rules(mesh, strategy.rules(mesh)):
        params = jax.jit(
            lambda k: model.init(k, cfg),
            out_shardings=jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                shd.param_pspecs(
                    jax.eval_shape(lambda k: model.init(k, cfg),
                                   jax.random.key(seed)),
                    strategy, mesh)),
        )(jax.random.key(seed))
    # jit dedups identical constants (e.g. the ln1/ln2 ones-vectors) into
    # ONE buffer; donation would then see the same buffer twice. Copy.
    return jax.tree.map(lambda x: x.copy(), params)


@dataclass
class TrainConfig:
    steps: int = 100
    lr: float = 3e-4
    log_every: int = 10
    checkpoint_every: int = 0            # 0 = disabled
    checkpoint_dir: str = "checkpoints"
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, strategy: Strategy, mesh: Mesh,
                 train_cfg: TrainConfig, data: Optional[TokenDataset] = None,
                 global_batch: int = 8, seq_len: int = 256, params=None):
        self.cfg, self.strategy, self.mesh = cfg, strategy, mesh
        self.tc = train_cfg
        self.data = data or TokenDataset(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len,
            global_batch=global_batch, seed=train_cfg.seed))
        self.global_batch, self.seq_len = global_batch, seq_len

        if params is None:
            params = init_sharded_params(cfg, strategy, mesh,
                                         seed=train_cfg.seed)
        else:
            # adamw's fp32 master is an astype no-op alias of the float32
            # tree it is built from, and the step DONATES opt_state — a
            # private copy keeps the caller's (e.g. a Session's) buffers
            # alive
            params = jax.tree.map(lambda x: x.copy(), params)
        self.opt_state = init_opt_state(params, strategy)
        # the step donates params AND opt_state: master aliases ``params``
        # above, so our param tree must be a second, distinct copy
        self.params = jax.tree.map(lambda x: x.copy(), params)
        step_fn = make_train_step(cfg, strategy, lr=train_cfg.lr)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shd.param_pspecs(params, strategy, mesh))
        osh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           shd.opt_state_pspecs(self.opt_state, params,
                                                strategy, mesh))
        # ZeRO-1 shards optimizer states differently from the params they
        # mirror — place them explicitly before the first donated step.
        self.opt_state = jax.device_put(self.opt_state, osh)
        self._osh = osh
        self.batch_sh = batch_shardings(cfg, global_batch, mesh, strategy)
        self._jit_step = jax.jit(step_fn, in_shardings=(psh, osh, None),
                                 out_shardings=(psh, osh, None),
                                 donate_argnums=(0, 1))
        self.step = 0
        self.history: list = []

    def maybe_restore(self):
        last = latest_step(self.tc.checkpoint_dir)
        if last is not None:
            self.params = load_checkpoint(self.tc.checkpoint_dir, last,
                                          self.params)
            # rebuild optimizer state: adamw derives the next params from
            # its fp32 master, so a master still holding the random init
            # would silently revert the restore on the first step. Init
            # from a copy — master must not alias the donated param tree.
            self.opt_state = jax.device_put(
                init_opt_state(jax.tree.map(lambda x: x.copy(), self.params),
                               self.strategy),
                self._osh)
            self.step = last
        return self.step

    def run(self, steps: Optional[int] = None) -> Dict[str, list]:
        steps = steps or self.tc.steps
        t0 = time.time()
        with sharding_rules(self.mesh, self.strategy.rules(self.mesh)):
            for i in range(steps):
                batch = self.data.batch(self.step)
                batch = {k: jax.device_put(v, self.batch_sh.get(k))
                         if k in self.batch_sh else v
                         for k, v in batch.items()}
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
                self.step += 1
                if self.step % self.tc.log_every == 0 or i == steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=self.step,
                             wall=round(time.time() - t0, 2))
                    self.history.append(m)
                    print(f"step {self.step:5d}  loss {m['loss']:.4f}  "
                          f"grad_norm {m['grad_norm']:.3f}  "
                          f"wall {m['wall']}s", flush=True)
                if (self.tc.checkpoint_every and
                        self.step % self.tc.checkpoint_every == 0):
                    save_checkpoint(self.tc.checkpoint_dir, self.step,
                                    self.params)
        return {"history": self.history}
