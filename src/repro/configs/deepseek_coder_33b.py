"""DeepSeek-Coder-33B — llama-arch dense, GQA kv=8 [arXiv:2401.14196]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b", arch_type="dense", source="arXiv:2401.14196",
    num_layers=62, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=19200, vocab_size=32256, rope_theta=100000.0,
)

# Dense full-attention: long_500k runs only via the sliding-window variant
# (window 4096), per DESIGN.md §4.
LONG_500K_POLICY = "swa"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-coder-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512,
    )
