"""Minitron-4B — pruned Nemotron, dense GQA kv=8 [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", arch_type="dense", source="arXiv:2407.14679",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000,
)

LONG_500K_POLICY = "swa"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512,
    )
