"""Mamba2-780M — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", arch_type="ssm", source="arXiv:2405.21060",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_heads=48, ssm_head_dim=64,  # expand=2: 48*64 = 2*d_model
)

# Constant-size recurrent state: long_500k runs natively.
LONG_500K_POLICY = "run"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", arch_type="ssm",
        num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm_state=16, ssm_heads=4, ssm_head_dim=64, ssm_chunk=32,
    )
