"""Kimi-K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe", source="arXiv:2501.kimi2",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    head_dim=112, d_ff=2048, vocab_size=163840,
    num_experts=384, experts_per_token=8,
    moe_capacity_factor=1.25,
)

LONG_500K_POLICY = "skip"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=64, vocab_size=512,
        num_experts=4, experts_per_token=2,
    )
