"""Llama-3.2-Vision-90B — cross-attention image layers [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision tower + projector are STUBBED per the assignment:
``input_specs()`` provides (B, num_image_tokens, d_model) patch embeddings.
100 layers = 80 self-attention + 20 cross-attention (one every 4 self layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", arch_type="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, rope_theta=500000.0,
    cross_attn_every=4, num_image_tokens=1601,
)

# Self-attention goes sliding-window at 500k; cross-attention is already
# O(num_image_tokens) per query.
LONG_500K_POLICY = "swa"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-vision-smoke", arch_type="vlm",
        num_layers=3, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512, cross_attn_every=2, num_image_tokens=16,
    )
