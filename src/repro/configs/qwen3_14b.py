"""Qwen3-14B — dense, GQA kv=8, qk_norm [hf:Qwen/Qwen3-8B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", arch_type="dense", source="hf:Qwen/Qwen3-8B",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=17408, vocab_size=151936,
    qk_norm=True, rope_theta=1000000.0,
)

LONG_500K_POLICY = "swa"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=512, qk_norm=True,
    )
