"""Model / run configuration system.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig`` (full size, dry-run only) and ``smoke() -> ModelConfig``
(reduced variant for CPU smoke tests). The registry in ``__init__`` maps
``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

ARCH_TYPES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    arch_type: str                      # one of ARCH_TYPES
    source: str = ""                    # citation for the config numbers

    # trunk
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4                  # 0 => attention-free (pure SSM)
    num_kv_heads: int = 4               # GQA KV heads
    head_dim: int = 0                   # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192
    tie_embeddings: bool = False

    # attention options
    qk_norm: bool = False               # qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    sliding_window: int = 0             # 0 => full attention; >0 => SWA width

    # MoE
    num_experts: int = 0                # 0 => dense FFN
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01       # load-balance loss weight

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0                  # N (state size per head); 0 => no SSM
    ssm_heads: int = 0                  # number of SSD heads
    ssm_head_dim: int = 64              # P (channels per head)
    ssm_chunk: int = 128                # SSD chunk length
    ssm_conv_width: int = 4             # short causal conv width

    # hybrid (zamba2-style): a SHARED full-attention block applied every k
    # mamba layers (weights shared across applications, caches are not).
    hybrid_attn_every: int = 0          # 0 => not hybrid

    # encoder-decoder (whisper-style). Frontend (mel+conv) is stubbed:
    # input_specs() provides (B, enc_ctx, d_model) frame embeddings.
    encoder_layers: int = 0
    encoder_ctx: int = 0                # e.g. 1500 audio frames

    # VLM (llama-3.2-vision-style): a cross-attention layer every k self-attn
    # layers. Vision tower is stubbed: input_specs() provides patch embeddings.
    cross_attn_every: int = 0           # 0 => not VLM
    num_image_tokens: int = 0           # e.g. 1601 patches

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5

    # deployment knobs (not architecture): context-parallel decode attention
    # (models/cp_attention.py) — shard-local cache writes + psum-softmax.
    cp_decode: bool = False

    def __post_init__(self):
        assert self.arch_type in ARCH_TYPES, self.arch_type
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived quantities (used by the cost model and docs) -------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.arch_type == "hybrid"

    @property
    def has_encoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def num_cross_layers(self) -> int:
        if self.cross_attn_every <= 0:
            return 0
        return self.num_layers // (self.cross_attn_every + 1)

    @property
    def num_self_layers(self) -> int:
        return self.num_layers - self.num_cross_layers

    def param_count(self) -> int:
        """Analytical parameter count (matches the initializers in models/)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += v * d                                  # embedding
        if not self.tie_embeddings:
            n += v * d                              # lm head
        if self.arch_type in ("dense", "moe", "vlm"):
            per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.is_moe:
                per_ffn = self.num_experts * (3 * d * f) + d * self.num_experts
            else:
                per_ffn = 3 * d * f                 # gated (SwiGLU) MLP
            per_layer = per_attn + per_ffn + 2 * d  # + norms
            n += self.num_self_layers * per_layer
            if self.num_cross_layers:
                per_cross = (d * self.q_dim + 2 * d * self.kv_dim
                             + self.q_dim * d + 3 * d * f + 3 * d)
                n += self.num_cross_layers * per_cross
        elif self.arch_type == "audio":
            per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            per_ffn = 2 * d * f                     # whisper uses plain GeLU MLP
            n += self.encoder_layers * (per_attn + per_ffn + 2 * d)
            n += self.num_layers * (2 * per_attn + per_ffn + 3 * d)
        elif self.arch_type in ("ssm", "hybrid"):
            H, P, N = self.ssm_heads, self.ssm_head_dim, self.ssm_state
            din = H * P
            per_ssm = (d * (2 * din + 2 * N + H)               # in_proj [z,x,B,C,dt] (G=1)
                       + (self.ssm_conv_width + 1) * (din + 2 * N)  # conv w+b
                       + H + 2 * H                              # dt_bias, A_log, D
                       + din                                    # gated-norm scale
                       + din * d + d)                           # out_proj + norm
            n += self.num_layers * per_ssm
            if self.is_hybrid:
                per_attn = (d * self.q_dim + 2 * d * self.kv_dim
                            + self.q_dim * d + 3 * d * self.d_ff + 2 * d)
                n += per_attn                       # ONE shared block
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense_experts = self.num_experts - self.experts_per_token
        return self.param_count() - self.num_self_layers * dense_experts * 3 * d * f

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One of the four assigned input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
