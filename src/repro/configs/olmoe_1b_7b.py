"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", arch_type="moe", source="arXiv:2409.02060",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1024, vocab_size=50304, num_experts=64, experts_per_token=8,
    rope_theta=10000.0,
)

# long_500k: full attention, no SWA variant in the source model -> skip.
LONG_500K_POLICY = "skip"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=64, vocab_size=512, num_experts=4, experts_per_token=2,
    )
