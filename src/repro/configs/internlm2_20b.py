"""InternLM2-20B — dense, GQA kv=8 [arXiv:2403.17297]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", arch_type="dense", source="arXiv:2403.17297",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544, rope_theta=1000000.0,
)

LONG_500K_POLICY = "swa"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-smoke", arch_type="dense",
        num_layers=2, d_model=256, num_heads=8, num_kv_heads=2,
        d_ff=512, vocab_size=512,
    )
