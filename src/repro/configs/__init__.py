"""Architecture registry: ``--arch <id>`` -> ModelConfig.

``get_config(name)`` returns the FULL config (dry-run only — never allocate).
``get_smoke(name)`` returns the reduced variant for CPU smoke tests.
``long_500k_policy(name)`` in {"run", "swa", "skip"} — see DESIGN.md §4.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, ShapeConfig, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
)

_MODULES = {
    "olmoe-1b-7b": "olmoe_1b_7b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-14b": "qwen3_14b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-780m": "mamba2_780m",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "internlm2-20b": "internlm2_20b",
    "minitron-4b": "minitron_4b",
}

ARCH_NAMES = tuple(_MODULES)


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_config(name: str) -> ModelConfig:
    return _mod(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _mod(name).smoke()


def long_500k_policy(name: str) -> str:
    return getattr(_mod(name), "LONG_500K_POLICY", "skip")
