"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid", source="arXiv:2411.15242",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_heads=64, ssm_head_dim=64,   # expand=2: 64*64 = 2*d_model
    hybrid_attn_every=6,                           # shared attn block every 6 mamba layers
)

# Hybrid (SSM-dominant) is sub-quadratic; the shared attention block uses a
# sliding window at 500k to keep its cache bounded.
LONG_500K_POLICY = "run"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", arch_type="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        ssm_state=16, ssm_heads=4, ssm_head_dim=64, ssm_chunk=32,
        hybrid_attn_every=2,
    )
