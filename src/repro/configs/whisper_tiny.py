"""Whisper-tiny — encoder-decoder audio transformer [arXiv:2212.04356].

The mel-spectrogram + conv frontend is STUBBED per the assignment:
``input_specs()`` provides (B, encoder_ctx, d_model) frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="audio", source="arXiv:2212.04356",
    num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865,
    encoder_layers=4, encoder_ctx=1500,
)

# Pure full attention, decoder context in the source model is 448; a 500k
# decode is meaningless for this arch -> skip (DESIGN.md §4.1).
LONG_500K_POLICY = "skip"


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", arch_type="audio",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, encoder_layers=2, encoder_ctx=64,
    )
