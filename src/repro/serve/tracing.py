"""Request-level tracing, step flight recorder, Perfetto export.

The survey's §4 loop (search → materialize → run) leaves the RUN half a
black box once the engine layers chunked prefill, speculation,
preemption and sharding on top of one traced program: aggregate
Prometheus summaries (serve/metrics.py) say THAT TTFT regressed, not
WHICH phase of WHICH step a given request spent its time in. This
module is the missing visibility layer, woven through the serve path by
PR 10 and deliberately dependency-free (stdlib only, like metrics.py):

  * **Per-request span trees** — every request accumulates typed events
    (``submitted``, ``admitted``, ``prefill_chunk``, ``decode``,
    ``first_token``, ``preempted``, ``expired``, ``completed``) stamped
    from the engine's existing lifecycle hooks, plus O(1) counters
    (generated tokens, prefill-chunk tokens, preemptions) that are
    default-on — the acceptance check "span tree matches the streamed
    token count" reads ``tokens`` straight off the trace.
  * **Per-step phase records** — :meth:`Tracer.begin_step` hands the
    engine a :class:`StepTrace` whose ``lap(phase)`` accumulates host
    wall time between call sites (draft / pack / dispatch / sync /
    bookkeeping...); the closed record also carries the step's work
    items (which slot decoded/prefilled what), so a step is attributable
    request by request. The driver drains per-step phase dicts into the
    ``serve_step_phase_seconds{phase=...}`` histograms.
  * **Flight recorder** — bounded ring buffers (``deque(maxlen=N)``) of
    the last N step records and recently finished request traces.
    :meth:`Tracer.flight` snapshots them on demand; the AsyncDriver's
    watchdog dumps the snapshot when a step overruns (replacing the
    PR 6 ad-hoc log dump), and ``GET /debug/flight`` serves it over
    HTTP — readable even while a stalled step holds the engine lock,
    because the tracer has its own tiny lock and the stalled thread is
    inside a device call, not inside the tracer.
  * **Chrome/Perfetto export** — :func:`chrome_trace` renders one or
    more tracers (one per DP replica) into the ``trace_event`` JSON
    object format: pid = replica, tid 0 = the engine-step lane (step
    spans with nested phase spans), tid 1+s = slot ``s``'s lane (one
    decode/prefill span per step, labeled with the rid and token
    counts). Load the file in https://ui.perfetto.dev or
    chrome://tracing. Request span trees ride in ``otherData``.

Overhead is bounded by construction: every hook is O(1) (append to a
ring or increment a counter), records live in fixed-size deques, and
``level`` gates the detail — 0 disables every hook (begin_step returns
the shared no-op :data:`NULL_STEP`), 1 (default) keeps lifecycle events
+ step records + counters, 2 adds a per-chunk / per-decode-step event to
the request span tree. Timestamps are ``time.perf_counter()`` — one
monotonic clock shared by every replica in the process, so merged lanes
line up.

Composition: a TP engine is still ONE engine → one tracer; a DP
``ReplicaRouter`` gives each replica its own tracer (``tracer.replica``
is stamped after construction) and merges them at export time —
per-lane ids never collide because the replica index is the pid.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

#: trace levels: OFF disables every hook, BASIC (default) records
#: lifecycle events + step records + per-request counters, DETAIL adds
#: per-chunk / per-decode-step events to the request span trees.
LEVEL_OFF, LEVEL_BASIC, LEVEL_DETAIL = 0, 1, 2


class _NullStep:
    """Shared no-op StepTrace stand-in for ``level=0`` — the engine's
    instrumentation calls land here branch-free."""

    __slots__ = ()

    def lap(self, phase: str):
        pass

    def note_decode(self, slot, rid, tokens, drafted=0, accepted=0):
        pass

    def note_chunk(self, slot, rid, start, count):
        pass


#: the singleton every disabled begin_step returns
NULL_STEP = _NullStep()


class StepTrace:
    """One engine step's record under construction (engine-thread local
    until :meth:`Tracer.end_step` publishes it into the ring).

    ``lap(phase)`` attributes the host time since the previous lap (or
    ``t0``) to ``phase``, accumulating on repeats — calling it at every
    section boundary partitions the step wall time with no gaps, which
    is what makes the exported phase spans cover ~100% of the step span
    (the acceptance bound is >= 95%)."""

    __slots__ = ("step_id", "t0", "_t", "dur", "produced", "phases",
                 "work")

    def __init__(self, step_id: int):
        self.step_id = step_id
        self.t0 = time.perf_counter()
        self._t = self.t0
        self.dur = 0.0
        self.produced = 0
        self.phases: Dict[str, float] = {}    # insertion-ordered laps
        self.work: List[dict] = []            # per-slot items this step

    def lap(self, phase: str):
        t = time.perf_counter()
        self.phases[phase] = self.phases.get(phase, 0.0) + (t - self._t)
        self._t = t

    def note_decode(self, slot: int, rid: int, tokens: int,
                    drafted: int = 0, accepted: int = 0):
        item = {"kind": "decode", "slot": int(slot), "rid": int(rid),
                "tokens": int(tokens)}
        if drafted:
            item["drafted"] = int(drafted)
            item["accepted_drafts"] = int(accepted)
        self.work.append(item)

    def note_chunk(self, slot: int, rid: int, start: int, count: int):
        self.work.append({"kind": "prefill", "slot": int(slot),
                          "rid": int(rid), "start": int(start),
                          "count": int(count)})

    def to_dict(self) -> dict:
        return {"step_id": self.step_id, "t0": self.t0, "dur": self.dur,
                "produced": self.produced,
                "phases": dict(self.phases), "work": list(self.work)}


class RequestTrace:
    """One request's span tree: typed events plus O(1) counters.

    ``tokens`` counts every generated token the engine appended to the
    request (prefill-sampled firsts included) — by construction it
    equals ``len(request.out)``, the streamed token count, which the
    tracing tests pin. ``events`` is bounded; overflow increments
    ``dropped`` instead of growing."""

    __slots__ = ("rid", "events", "tokens", "chunk_tokens",
                 "preemptions", "dropped", "max_events", "done",
                 "outcome")

    def __init__(self, rid: int, max_events: int = 256):
        self.rid = rid
        self.events: List[tuple] = []     # (t, kind, fields|None)
        self.tokens = 0
        self.chunk_tokens = 0
        self.preemptions = 0
        self.dropped = 0
        self.max_events = max_events
        self.done = False
        self.outcome: Optional[str] = None

    def add(self, kind: str, fields: Optional[dict]):
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append((time.perf_counter(), kind, fields))

    def to_dict(self) -> dict:
        return {
            "rid": self.rid, "tokens": self.tokens,
            "chunk_tokens": self.chunk_tokens,
            "preemptions": self.preemptions, "done": self.done,
            "outcome": self.outcome, "dropped_events": self.dropped,
            "events": [
                {"t": t, "kind": kind, **(fields or {})}
                for t, kind, fields in self.events]}


class Tracer:
    """The engine-side recorder: request span trees + step flight ring.

    One tracer per :class:`~repro.serve.engine.ServeEngine` (a TP engine
    is still one engine); a DP router stamps each replica's
    ``tracer.replica`` after construction so merged exports get distinct
    pid lanes. Thread-safety: every mutation of the shared rings/maps
    happens under one small lock; a StepTrace is engine-thread-local
    until published. The HTTP/watchdog threads only ever read through
    :meth:`flight` / :func:`chrome_trace`, which snapshot under the same
    lock — safe to call while a stalled step holds the DRIVER lock,
    since the stalled thread is inside a device call, not in here."""

    def __init__(self, level: int = LEVEL_BASIC, *, max_steps: int = 256,
                 max_requests: int = 64, max_events: int = 256,
                 replica: int = 0):
        if max_steps < 1 or max_requests < 1 or max_events < 1:
            raise ValueError("tracer ring sizes must be >= 1")
        self.level = int(level)
        self.replica = int(replica)
        self.max_events = int(max_events)
        self._lock = threading.Lock()
        self.steps: Deque[StepTrace] = deque(maxlen=max_steps)
        self._live: Dict[int, RequestTrace] = {}
        self._done: Deque[RequestTrace] = deque(maxlen=max_requests)
        # per-step phase dicts awaiting the driver's histogram drain;
        # bounded so a batch run (no driver) cannot grow it
        self._pending: Deque[tuple] = deque(maxlen=max_steps)
        self.dropped_requests = 0

    @property
    def enabled(self) -> bool:
        return self.level >= LEVEL_BASIC

    # -------------------------------------------------------- step hooks
    def begin_step(self, step_id: int):
        """A fresh :class:`StepTrace` (or :data:`NULL_STEP` when
        disabled) — the engine laps phases on it and hands it back to
        :meth:`end_step`."""
        if self.level < LEVEL_BASIC:
            return NULL_STEP
        return StepTrace(step_id)

    def end_step(self, st, produced: int):
        """Publish a finished StepTrace into the flight ring (and the
        driver's pending-phases queue)."""
        if st is NULL_STEP or self.level < LEVEL_BASIC:
            return
        st.dur = time.perf_counter() - st.t0
        st.produced = int(produced)
        with self._lock:
            self.steps.append(st)
            self._pending.append((st.step_id, dict(st.phases), st.dur))

    def drain_phases(self) -> List[tuple]:
        """Pop every pending ``(step_id, phases, dur)`` triple — the
        driver observes them into ``serve_step_phase_seconds``."""
        with self._lock:
            out = list(self._pending)
            self._pending.clear()
        return out

    # ----------------------------------------------------- request hooks
    def _req(self, rid: int) -> RequestTrace:
        # caller holds the lock
        rt = self._live.get(rid)
        if rt is None:
            if len(self._live) >= 4096:      # runaway guard, not a limit
                self.dropped_requests += 1
                return RequestTrace(rid, max_events=1)
            rt = RequestTrace(rid, max_events=self.max_events)
            self._live[rid] = rt
        return rt

    def req_event(self, rid: int, kind: str, **fields):
        """Append a lifecycle event to ``rid``'s span tree (level >= 1)."""
        if self.level < LEVEL_BASIC:
            return
        with self._lock:
            self._req(rid).add(kind, fields or None)

    def req_detail(self, rid: int, kind: str, **fields):
        """Append a per-chunk / per-decode-step event (level >= 2 only —
        the O(step) detail the default level keeps out of the tree)."""
        if self.level < LEVEL_DETAIL:
            return
        with self._lock:
            self._req(rid).add(kind, fields or None)

    def req_tokens(self, rid: int, n: int):
        """Count ``n`` freshly generated tokens against ``rid``."""
        if self.level < LEVEL_BASIC:
            return
        with self._lock:
            self._req(rid).tokens += int(n)

    def req_chunk_tokens(self, rid: int, n: int):
        if self.level < LEVEL_BASIC:
            return
        with self._lock:
            self._req(rid).chunk_tokens += int(n)

    def req_preempted(self, rid: int, **fields):
        if self.level < LEVEL_BASIC:
            return
        with self._lock:
            rt = self._req(rid)
            rt.preemptions += 1
            rt.add("preempted", fields or None)

    def finish_request(self, rid: int, outcome: str, **fields):
        """Close ``rid``'s span tree (``completed`` or ``expired``) and
        move it from the live map to the finished ring."""
        if self.level < LEVEL_BASIC:
            return
        with self._lock:
            rt = self._live.pop(rid, None)
            if rt is None:
                rt = RequestTrace(rid, max_events=self.max_events)
            rt.add(outcome, fields or None)
            rt.done = True
            rt.outcome = outcome
            self._done.append(rt)

    def request_trace(self, rid: int) -> Optional[dict]:
        """The span tree for ``rid`` (live or recently finished)."""
        with self._lock:
            rt = self._live.get(rid)
            if rt is None:
                for cand in self._done:
                    if cand.rid == rid:
                        rt = cand
                        break
            return rt.to_dict() if rt is not None else None

    # ---------------------------------------------------- flight recorder
    def flight(self, last: Optional[int] = None) -> dict:
        """Snapshot of the ring buffers: the most recent ``last`` step
        records (all when None) plus live and recently finished request
        traces — the watchdog's dump and ``GET /debug/flight``."""
        with self._lock:
            steps = list(self.steps)
            live = [rt.to_dict() for rt in self._live.values()]
            done = [rt.to_dict() for rt in self._done]
        if last is not None:
            steps = steps[-last:]
        return {"replica": self.replica, "level": self.level,
                "steps": [st.to_dict() for st in steps],
                "live_requests": live, "finished_requests": done,
                "dropped_requests": self.dropped_requests}

    # ------------------------------------------------------------ export
    def export(self, path: str) -> dict:
        """Write this tracer's Chrome ``trace_event`` JSON to ``path``
        and return the object (see :func:`export_chrome_trace` for the
        multi-replica merge)."""
        return export_chrome_trace(path, [self])


# -------------------------------------------------- Chrome trace assembly
def _us(t: float) -> float:
    """perf_counter seconds -> trace_event microseconds."""
    return t * 1e6


def chrome_trace(tracers: Sequence[Tracer]) -> dict:
    """Merge one tracer per replica into one Chrome ``trace_event``
    object: ``{"traceEvents": [...], "otherData": {...}}``.

    Lanes: pid = the tracer's replica index (process rows in Perfetto),
    tid 0 = the engine-step lane — one complete ("X") span per step with
    its phase spans nested inside — and tid ``1 + slot`` = that slot's
    lane, one span per step describing the decode run or prefill chunk
    the slot performed (rid + token counts in ``args``). Per-lane
    timestamps are non-decreasing (step records are ring-ordered and a
    slot does at most one work item per step), which the CI trace-smoke
    job asserts. Request span trees ride in
    ``otherData["requests"]`` keyed by replica."""
    events: List[dict] = []
    requests: Dict[str, list] = {}
    for tr in tracers:
        snap = tr.flight()
        pid = snap["replica"]
        events.append({"name": "process_name", "ph": "M", "pid": pid,
                       "tid": 0,
                       "args": {"name": f"replica {pid}"}})
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": 0, "args": {"name": "engine steps"}})
        slots_seen = set()
        for rec in snap["steps"]:
            ts0 = _us(rec["t0"])
            events.append({
                "name": f"step {rec['step_id']}", "cat": "step",
                "ph": "X", "ts": ts0, "dur": _us(rec["dur"]),
                "pid": pid, "tid": 0,
                "args": {"produced": rec["produced"],
                         "phases_s": rec["phases"]}})
            t = ts0
            for phase, sec in rec["phases"].items():
                events.append({
                    "name": phase, "cat": "phase", "ph": "X",
                    "ts": t, "dur": _us(sec), "pid": pid, "tid": 0,
                    "args": {}})
                t += _us(sec)
            for item in rec["work"]:
                s = item["slot"]
                slots_seen.add(s)
                if item["kind"] == "decode":
                    name = f"decode r{item['rid']}"
                    args = {k: item[k] for k in
                            ("rid", "tokens", "drafted",
                             "accepted_drafts") if k in item}
                else:
                    name = (f"prefill r{item['rid']} "
                            f"[{item['start']},"
                            f"{item['start'] + item['count']})")
                    args = {"rid": item["rid"], "start": item["start"],
                            "count": item["count"]}
                events.append({
                    "name": name, "cat": item["kind"], "ph": "X",
                    "ts": ts0, "dur": _us(rec["dur"]), "pid": pid,
                    "tid": 1 + s, "args": args})
        for s in sorted(slots_seen):
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": 1 + s,
                           "args": {"name": f"slot {s}"}})
        requests[str(pid)] = (snap["live_requests"]
                              + snap["finished_requests"])
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"clock": "perf_counter_us",
                          "requests": requests}}


def export_chrome_trace(path: str, tracers: Sequence[Tracer]) -> dict:
    """Serialize :func:`chrome_trace` of ``tracers`` to ``path``
    (Perfetto/chrome://tracing-loadable JSON); returns the object."""
    obj = chrome_trace(tracers)
    with open(path, "w") as f:
        json.dump(obj, f)
    return obj


def phase_coverage(tracers: Sequence[Tracer]) -> float:
    """Fraction of recorded step wall time the phase laps account for —
    1.0 when every section between begin_step and end_step was lapped
    (the acceptance bound is >= 0.95). NaN-free: 1.0 with no steps."""
    tot = cov = 0.0
    for tr in tracers:
        for rec in tr.flight()["steps"]:
            tot += rec["dur"]
            cov += sum(rec["phases"].values())
    return cov / tot if tot > 0 else 1.0
