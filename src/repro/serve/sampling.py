"""On-device sampling, shared by ``ServeEngine`` and ``greedy_generate``.

One hook so every decode path samples identically: ``temperature <= 0``
(or no rng) is exact greedy argmax; otherwise temperature-scaled
categorical sampling via Gumbel-max (``jax.random.categorical``). The hook
is pure and shape-polymorphic — it runs INSIDE the jitted decode step, so
sampling costs no extra device dispatch.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample_tokens(logits, *, rng: Optional[jax.Array] = None,
                  temperature: float = 0.0):
    """logits (..., V) -> sampled token ids (...,) int32."""
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)
