"""Serving engine: continuous-batching-lite over the decode step.

A fixed-size slot table (the batch) holds independent requests at
different generation depths. Because the model-side decode_step takes a
single scalar ``pos`` (the production dry-run shape), the engine tracks
per-slot positions and uses the PADDED decode trick: every slot steps with
the same cache write cursor, but finished/empty slots are masked and their
sampled tokens discarded. Admission fills free slots from a queue between
steps (the standard orca/vllm-style outer loop, minus paged KV).

This is deliberately host-side Python around the jitted step — the jitted
inner step is shape-stable so the engine never recompiles after warmup.

Preferred construction: ``repro.api.Session.serve(slots=..., max_len=...)``
— the Session supplies the params (freshly initialised, restored from a
checkpoint, or just trained) so callers never thread param trees by hand.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None):
        self.cfg, self.params = cfg, params
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        # FIFO admission queue: deque so heavy-traffic admission stays O(1)
        # per pop (a list's pop(0) is O(n) in queued requests)
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: Dict[int, Request] = {}
        self._caches: List[Optional[dict]] = [None] * slots
        self._step = jax.jit(
            lambda p, c, t, i: self.model.decode_step(p, c, t, i, cfg))

    def submit(self, rid: int, prompt: np.ndarray, max_new: int):
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_new))

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                cache = self.model.init_cache(self.cfg, 1, self.max_len)
                logits, cache = self.model.prefill(
                    self.params, {"tokens": req.prompt[None, :]}, self.cfg,
                    cache)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out.append(tok)
                self.active[s] = req
                self._caches[s] = cache

    def _retire(self, s: int):
        req = self.active[s]
        req.done = True
        self.finished[req.rid] = req
        self.active[s] = None
        self._caches[s] = None

    def step(self):
        """One decode step for every active slot."""
        self._admit()
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            cache = self._caches[s]
            pos = int(cache["pos"])
            tok = jnp.asarray([[req.out[-1]]], jnp.int32)
            logits, cache = self._step(self.params, cache, tok,
                                       jnp.asarray(pos, jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            self._caches[s] = cache
            hit_eos = self.eos_id is not None and nxt == self.eos_id
            if len(req.out) >= req.max_new or hit_eos or \
                    pos + 1 >= self.max_len:
                self._retire(s)

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or any(self.active)) and steps < max_steps:
            self.step()
            steps += 1
        return {rid: r.out for rid, r in self.finished.items()}
