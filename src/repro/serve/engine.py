"""Serving engine: continuous batching over ONE batched decode step.

A fixed-size slot table (the batch) holds independent requests at
different generation depths. The whole table advances with a SINGLE
jitted decode call per engine step: positions are a per-slot vector, and
``decode_step`` scatters each row's new KV at its own cursor while the
attention mask keeps each row inside its own valid prefix. Finished/empty
slots are masked on device — their sampled tokens are zeroed and their
cursors frozen — so device dispatch per step is O(1) in the number of
active slots.

KV layouts (models/kvcache.py):

  * PAGED (default where supported — vLLM-style block tables): one flat
    pool of ``page_size``-token pages shared by every slot, plus a
    per-slot page table. Admission reserves pages from a host-side
    refcounted free-list (serve/paging.py) and releases them when the
    request retires, so a short request holds pages for ITS context, not
    a dense ``max_len`` row. The page table is a device array whose
    VALUES change at admission/retire while its shape never does, so the
    whole run still traces exactly one decode program.
  * DENSE (``paged=False``, and the automatic fallback): one contiguous
    ``max_len`` (or ring-window) row per slot. Sliding-window (ring) and
    SSM/hybrid archs keep this layout — a ring cache is already O(window)
    and the SSM state is O(1), so pages would add indirection for no
    memory win.

On the paged layout three independent features stack (all off by
default, preserving the PR 3 worst-case-reservation behaviour):

  * ``prefix_cache=True`` — a radix tree over page-aligned token blocks
    (serve/prefix.py) maps shared prompt prefixes to refcounted pool
    pages, so N requests with a common system prompt hold ONE physical
    copy. Exact for dense decoders (causal KV depends only on the
    prefix); enc-dec keys additionally on a digest of the request's
    frames, and MoE on a digest of the full context (capacity routing
    makes block KV portable only between identical contexts). Registered
    pages stay resident after their owner retires (cheap re-prefill for
    repeat prompts and preempted victims) and are evicted LRU-first
    under pool pressure.
  * ``lazy=True`` — admission reserves only ``ceil((len(prompt +
    emitted) + 1) / page_size)`` pages — the prompt plus its first
    decode write, one page beyond the prompt's only when it ends on a
    page boundary — instead of the worst-case
    ``ceil((prompt + max_new - 1) / page_size)``;
    ``step`` grows the reservation when a slot's cursor crosses a page
    boundary. The pool can now run dry MID-DECODE: the engine then
    evicts cold prefix pages and, if still short, PREEMPTS the
    least-progress slot (serve/scheduler.py) — the victim's private
    pages are freed (prefix pages merely drop a reference), and the
    request is requeued at the FIFO head with its partial output; its
    re-prefill over prompt+output resumes decoding exactly (greedy
    decode is bit-identical to the uninterrupted run). Lazy mode also
    unlocks partial-tail prefix hits, whose adopted page is duplicated
    by COPY-ON-WRITE (``allocator.cow`` + ``kvcache.copy_page``) before
    the slot's first decode write lands in it.
  * ``scheduler=`` — the admission/preemption policy object; the default
    ``FifoLeastProgress`` keeps FIFO head-of-line admission and preempts
    the fewest-generated-tokens slot first.

All of it is host-side bookkeeping plus page-table VALUES — prefill and
decode stay exactly one trace each, sharing or not (asserted by the CI
paged-serve smoke and tests/test_serve_prefix.py).

MIXED token-slot stepping (default on the paged layout, PR 7): instead
of the two-program prefill/decode split, each step runs ONE program over
a ``chunk_tokens``-token batch — every decoding slot's next token first,
then prefill CHUNKS of admitted-but-unprefilled requests
(sglang/vLLM-style chunked prefill). Admission only reserves pages and
enqueues the prefill work; the step loop drains it cursor-by-cursor
through the paged KV scatter, sampling a request's first token on the
chunk containing its final prompt position. A long prompt therefore
never stalls decoding slots — it shares each step's budget with them
(TTFT p99 under mixed workloads is the win the bench's
``--mixed-workload`` mode measures). Chunking is EXACT: the program
scatters every chunk token's K/V before the attention gathers, so greedy
output is bit-identical to the legacy split path (test-pinned for dense,
MoE and enc-dec, tp2/dp2 included), and the batch is statically
``chunk_tokens`` wide, so the program retraces once per page bucket —
trace count stays bounded. ``mixed=False`` keeps the legacy split path
on the paged layout; dense-layout archs (SWA ring, SSM/hybrid) always
use it.

Legacy admission fills free slots from a FIFO queue between steps (the
standard orca/vllm outer loop). Prefill pads prompts to power-of-two
buckets (serve/step.prefill_bucket) so XLA retraces at most
log2(max_len) prefill shapes; paged prefill additionally rounds the
bucket up to whole pages and scatters the fresh KV page-wise
(serve/step.scatter_prefill_pages), skipping blocks the prefix cache
already holds. Sampling (greedy or temperature) runs on device inside
the same jitted step (serve/sampling.py).

Caveats: MoE archs skip prompt bucketing, and their batched decode can
differ from single-request decode — capacity-based expert routing couples
rows of a batch (pad/neighbour tokens consume expert capacity). Dense,
SSM and hybrid archs are row-independent and token-identical to
sequential decoding. Enc-dec (audio) requests must carry precomputed
frame embeddings (``submit(..., frames=...)`` — the mel+conv frontend is
the assignment's allowed stub); their decoder KV pages like any dense
decoder while the cross-attention KV stays one fixed-size block per slot.
Preemption keeps greedy outputs bit-identical for row-independent archs
(resume-by-re-prefill recomputes exactly the KV the victim held); MoE
extends its standing caveat — a re-prefill routes the whole context under
prefill capacity, where the uninterrupted run would have routed the tail
token-by-token — and with ``temperature > 0`` a preempted request resumes
on a different rng draw (stochastic either way).

Intra-operator (TP) sharded serving (``mesh=``)
-----------------------------------------------
Passing a ("data", "model") mesh (+ the Strategy whose rules map logical
axes onto it) runs the SAME one-trace prefill/decode programs sharded
GSPMD-style across the mesh's ``tp`` devices: params take the Megatron
§5.1 layout (core/sharding.param_pspecs), and the paged pool keeps its
flat ``(L, n_pages, page_size, Hkv, D)`` shape but is HEAD-SHARDED over
"model" — each device holds ``Hkv/tp`` heads of every page, so resident
per-device KV is ~1/tp of the unsharded pool while the page axis stays
whole (the block-table gather indexes it). The page table, cursors and
sampled logits are replicated; admission/retire still only rewrites
table VALUES, so the one-decode-trace invariant survives sharding
(tests/test_serve_parallel.py pins tp=2 token parity vs tp=1). Data
parallelism is one level up: ``serve/parallel.ReplicaRouter``
instantiates ``dp`` engine replicas over disjoint device slices and
routes requests between them.

Decode cost tracks OCCUPANCY, not capacity: the page table handed to the
decode program is clipped to the power-of-two bucket of the live page
high-water mark — the allocator's per-owner peak, with every admission's
worst-case reservation pre-booked so lazy growth never re-buckets
mid-decode (serve/step.page_bucket, ``_sync_ptab``). The paged-attention
gather then reads ``bucket * page_size`` positions per row instead of
the full ``max_len`` table width, and the program retraces only when an
admission pushes the high-water across a bucket boundary.

``engine.stats`` counts device calls AND traces (``decode_traces`` /
``prefill_traces`` increment only while tracing), so tests can assert the
one-program property directly — plus pool telemetry: ``pages_in_use`` /
``peak_pages``, prefix-cache ``prefix_hit_blocks`` /
``prefix_miss_blocks`` / ``prefix_tail_hits`` / ``prefix_evictions``,
``preemptions`` and ``cow_copies``.

Preferred construction: ``repro.api.Session.serve(slots=..., max_len=...,
page_size=..., prefix_cache=..., lazy=...)`` — the Session supplies the
params so callers never thread param trees by hand, and its ``plan=`` /
``tp=`` / ``dp=`` arguments pick sharded/replicated serving.
"""
from __future__ import annotations

import hashlib
import time
from collections import deque
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import sharding as shd
from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.models import get_model, kvcache
from repro.serve.paging import PageAllocator, pages_for
from repro.serve.prefix import PrefixCache
from repro.serve.sampling import sample_tokens
from repro.serve.scheduler import FifoLeastProgress
from repro.serve.step import (pack_token_budget, page_bucket,
                              prefill_bucket, scatter_prefill_pages)
from repro.serve.tracing import NULL_STEP, Tracer, chrome_trace, \
    export_chrome_trace

#: archs the token-only engine can serve without per-request extras.
TOKEN_ONLY_ARCHS = ("dense", "moe", "ssm", "hybrid")
#: + enc-dec audio (stubbed frame embeddings) and VLM (stubbed image
#: patch embeddings) — each request carries its modality tensor.
SERVABLE_ARCHS = TOKEN_ONLY_ARCHS + ("audio", "vlm")
#: archs whose decode cache can use the paged (block-table) layout.
PAGEABLE_ARCHS = ("dense", "moe", "audio")


@dataclass
class Request:
    """One request's lifecycle record; ``run()`` returns these so callers
    can distinguish completion (``done=True``) from truncation by
    ``max_steps`` (``done=False`` with partial/empty ``out``). A preempted
    request keeps its partial ``out`` while requeued — re-admission
    prefills over prompt+out and resumes. A request whose ``deadline``
    passes while still QUEUED finishes ``done=False, expired=True``
    instead of occupying the scheduler's head."""
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    frames: Optional[np.ndarray] = None   # (enc_ctx, d_model), audio archs
    images: Optional[np.ndarray] = None   # (n_img_tok, d_model), vlm archs
    priority: int = 0                  # scheduler hint (serve/scheduler.py)
    deadline: Optional[float] = None   # absolute time.monotonic() SLO bound
    expired: bool = False              # deadline passed while queued
    # host timestamp of the FIRST generated token (set at prefill
    # completion, so TTFT covers requests that finish at admission)
    first_tok_t: Optional[float] = field(default=None, repr=False)
    # memoized (ctx_len, salt) — a backpressured head-of-line request
    # re-places every step and must not re-hash its frames/context
    salt_cache: Optional[tuple] = field(default=None, repr=False)


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0, paged: Optional[bool] = None,
                 page_size: int = 16, kv_pages: Optional[int] = None,
                 prefix_cache: bool = False, lazy: bool = False,
                 scheduler=None, mesh=None, strategy=None,
                 mixed: Optional[bool] = None, chunk_tokens: int = 256,
                 attn_backend: str = "gather", spec=None,
                 tracer=None, trace_level: int = 1):
        if cfg.arch_type not in SERVABLE_ARCHS:
            raise ValueError(
                f"{cfg.name}: the engine drives token/frame decoders "
                f"({'/'.join(SERVABLE_ARCHS)}), not {cfg.arch_type}")
        pageable = (cfg.arch_type in PAGEABLE_ARCHS
                    and cfg.sliding_window == 0)
        if (prefix_cache or lazy) and not pageable:
            raise ValueError(
                f"{cfg.name}: prefix_cache/lazy ride on the paged KV "
                f"pool, which needs a full-attention decoder "
                f"({'/'.join(PAGEABLE_ARCHS)}, no sliding window) — "
                f"unavailable for {cfg.arch_type}"
                + (" + SWA ring" if cfg.sliding_window else ""))
        if paged is None:
            # auto: paged for every full-attention decoder. Exact vs dense
            # for row-independent archs; MoE keeps its standing batched-
            # routing caveat (see module docstring) under either layout.
            # prefix_cache/lazy are paged-pool features, so requesting
            # them resolves auto to paged.
            paged = True if (prefix_cache or lazy) else pageable
        if paged and not pageable:
            raise ValueError(
                f"{cfg.name}: paged KV needs a full-attention decoder "
                f"({'/'.join(PAGEABLE_ARCHS)}, no sliding window); "
                f"{cfg.arch_type}"
                + (" + SWA ring" if cfg.sliding_window else "")
                + " keeps the dense layout (paged=False)")
        if (prefix_cache or lazy) and not paged:
            raise ValueError(
                f"{cfg.name}: prefix_cache/lazy ride on the paged pool; "
                "drop paged=False to use them")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        # -------- mixed token-slot stepping (chunked prefill, PR 7):
        # ONE program per step processes every active slot's decode token
        # plus prefill CHUNKS of admitted-but-unprefilled requests inside
        # a bounded token budget. Default wherever the paged layout is —
        # chunking rides on the page-table scatter/gather; dense layouts
        # keep the legacy two-program split.
        if mixed is None:
            mixed = bool(paged)
        if mixed and not paged:
            raise ValueError(
                f"{cfg.name}: the mixed token-slot step writes prefill "
                "chunks through the paged KV scatter; drop paged=False "
                "(or pass mixed=False) to serve this arch")
        if mixed and chunk_tokens < max(slots, 1):
            raise ValueError(
                f"chunk_tokens ({chunk_tokens}) must be >= slots "
                f"({slots}): every active slot's decode token is "
                "reserved in the budget before any prefill chunk")
        self.mixed = bool(mixed)
        self.chunk_tokens = int(chunk_tokens)
        # -------- paged-attention decode backend (PR 8): "gather" keeps
        # the XLA gather + dense-mask path; "pallas" runs the fused
        # flash-decoding kernel (kernels/paged_attention.py — interpret
        # mode on CPU). Token-identical greedy outputs, same one-trace-
        # per-bucket cadence; the kernel only exists for the paged pool.
        if attn_backend not in ("gather", "pallas"):
            raise ValueError(
                f"attn_backend must be 'gather' or 'pallas', "
                f"got {attn_backend!r}")
        if attn_backend == "pallas" and not paged:
            raise ValueError(
                f"{cfg.name}: attn_backend='pallas' is the fused paged-"
                "attention decode kernel — it needs the paged KV layout "
                "(drop paged=False)")
        self.attn_backend = attn_backend
        # only the paged decoders (transformer/encdec decode_step) take
        # the kwarg; the default backend stays a clean positional call so
        # ssm/hybrid decode paths are untouched
        self._attn_kw = {} if attn_backend == "gather" \
            else {"attn_backend": attn_backend}
        # -------- speculative multi-token decode (PR 9): a SpecConfig
        # turns each decoding slot's one row into 1 + k rows of the SAME
        # mixed program — drafted tokens at consecutive positions,
        # verified in one dispatch, longest greedy-matching prefix
        # accepted (+1 bonus). serve/speculative.py holds the drafters.
        self.spec = spec
        self._drafter = None
        if spec is not None:
            if not mixed:
                raise ValueError(
                    f"{cfg.name}: speculative decode packs draft rows "
                    "into the mixed token-slot step; it needs the paged "
                    "layout with mixed=True (the default there)")
            if temperature > 0.0:
                raise ValueError(
                    "speculative decode is greedy-only (temperature "
                    f"0.0, got {temperature}): acceptance compares the "
                    "verifier's argmax tokens — stochastic speculative "
                    "sampling is a different acceptance rule")
            if chunk_tokens < max(slots, 1) * (spec.k + 1):
                raise ValueError(
                    f"chunk_tokens ({chunk_tokens}) must be >= slots * "
                    f"(spec.k + 1) = {slots * (spec.k + 1)}: every "
                    "slot's base decode row plus its k draft rows is "
                    "reserved in the budget before any prefill chunk")
            from repro.serve.speculative import make_drafter
            self._drafter = make_drafter(spec, cfg, max_len=max_len,
                                         seed=seed)
        # -------- intra-operator (TP) sharding: mesh + logical-axis rules
        self.mesh = mesh
        self.tp = int(mesh.shape.get("model", 1)) if mesh is not None else 1
        if mesh is not None:
            self.strategy = strategy if strategy is not None \
                else Strategy(dtype=cfg.dtype)
            self._rules = self.strategy.rules(mesh)
            # Megatron param layout on the engine's mesh (a no-op when the
            # caller already sharded them there)
            params = jax.device_put(
                params, shd.param_shardings(params, self.strategy, mesh))
            self._ctx = lambda: sharding_rules(self.mesh, self._rules)
        else:
            self.strategy = strategy
            self._ctx = nullcontext
        self.cfg, self.params = cfg, params
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.paged = paged
        self.page_size = page_size
        self.lazy = lazy
        self.prefix_cache = prefix_cache
        # FIFO admission queue: deque so heavy-traffic admission stays O(1)
        # per pop (a list's pop(0) is O(n) in queued requests)
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: Dict[int, Request] = {}
        self.stats = {"decode_steps": 0, "decode_traces": 0,
                      "prefills": 0, "prefill_traces": 0,
                      "pages_in_use": 0, "peak_pages": 0,
                      "prefix_hit_blocks": 0, "prefix_miss_blocks": 0,
                      "prefix_tail_hits": 0, "prefix_evictions": 0,
                      "preemptions": 0, "cow_copies": 0,
                      # online telemetry (PR 6): step_count counts every
                      # step() call (decode_steps only the ones that ran
                      # the device program), decode_tokens every token
                      # generated (prefill-sampled ones included),
                      # wall_time_s the host seconds spent inside step(),
                      # tokens_per_s_ewma a smoothed generation rate —
                      # the DP router's routing signal —, and
                      # prefix_decode_blocks the page-aligned blocks
                      # registered from DECODE output (prompt blocks are
                      # counted by the prefix hit/miss pair)
                      "step_count": 0, "decode_tokens": 0,
                      "wall_time_s": 0.0, "tokens_per_s_ewma": 0.0,
                      "prefix_decode_blocks": 0,
                      # mixed-step telemetry (PR 7): prefill tokens
                      # processed as chunks, deadline-expired queued
                      # requests, audio encoder traces (the mixed path
                      # runs the encoder as its own small program)
                      "prefill_chunk_tokens": 0, "expired": 0,
                      "encode_traces": 0,
                      # speculative decode (PR 9): drafted tokens packed
                      # as verify rows, and how many the verifier
                      # accepted (bonus tokens are ordinary decode
                      # tokens, not counted here) — accept rate is
                      # spec_accepted / spec_drafted;
                      # decode_slot_steps counts (step, decoding slot)
                      # pairs — the honest denominator for tokens-per-
                      # step: (decode_tokens - prefills) over it is
                      # exactly 1.0 without speculation and in
                      # [1, k + 1] with it, slot count notwithstanding
                      "spec_drafted": 0, "spec_accepted": 0,
                      "decode_slot_steps": 0,
                      # which paged-attention path the decode program
                      # runs (PR 8); a string — metrics render it as a
                      # labeled serve_engine_decode_backend info gauge
                      "decode_backend": attn_backend}
        # -------- observability (PR 10, serve/tracing.py): request span
        # trees + per-step phase records + flight-recorder rings. Always
        # present — trace_level=0 turns every hook into an O(1) no-op;
        # the default level keeps lifecycle events and step records,
        # level 2 adds per-chunk detail to the request trees. A DP
        # router stamps each replica's ``tracer.replica`` after
        # construction so merged exports get distinct lanes.
        self.tracer = tracer if tracer is not None \
            else Tracer(level=trace_level)
        self._rng = jax.random.key(seed)
        self._sched = scheduler if scheduler is not None \
            else FifoLeastProgress()
        # the slot table: one batched cache, per-slot position vector
        self._cache = self.model.init_cache(cfg, slots, max_len)
        self._cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._pos = np.zeros(slots, np.int64)    # host mirror: tokens in ctx
        self._last = np.zeros(slots, np.int64)   # host mirror: last token
        self._prefix: Optional[PrefixCache] = None
        if paged:
            # swap the dense per-slot rows for a flat page pool + table;
            # page 0 is the null page (inactive-slot / padding scratch)
            pps = pages_for(max_len, page_size)  # table width: blocks/slot
            self.kv_pages = kv_pages if kv_pages is not None \
                else slots * pps
            if self.kv_pages < 1:
                raise ValueError(
                    f"kv_pages must be >= 1, got {self.kv_pages}")
            dtype = self._cache["kv"]["k"].dtype
            self._cache["kv"] = kvcache.init_paged_kv(
                cfg.num_layers, self.kv_pages + 1, page_size,
                cfg.num_kv_heads, cfg.head_dim, dtype)
            # the DEVICE page table is clipped to the power-of-two bucket
            # of the allocator's per-slot page high-water mark (_sync_ptab)
            # so the decode gather reads occupancy, not max_len; the host
            # mirror stays full-width
            self._pps = pps
            self._gather = 1
            self._hw_blocks = 1
            self._cache["ptab"] = jnp.zeros((slots, self._gather), jnp.int32)
            self._ptab = np.zeros((slots, pps), np.int64)
            self._ptab_dirty = False
            self._alloc = PageAllocator(self.kv_pages, page_size,
                                        first_page=1)
            if prefix_cache:
                self._prefix = PrefixCache(self._alloc, page_size)
            self._copy_page = jax.jit(kvcache.copy_page,
                                      donate_argnums=(0,))
        if mesh is not None:
            # place the decode state onto the mesh: pool head-sharded over
            # "model", dense leaves per the usual cache rules, page table /
            # cursors replicated (core/sharding.cache_pspecs)
            self._cache = jax.device_put(
                self._cache,
                jax.tree.map(lambda s: NamedSharding(mesh, s),
                             shd.cache_pspecs(self._cache, self.strategy,
                                              mesh, slots)))
        # bucketing: attention masks make right-padding exact for dense;
        # MoE capacity routing and the SSM recurrence are perturbed by pad
        # tokens (and enc-dec prefill gathers no last_pos), so those archs
        # prefill at exact length (retrace per len).
        self._bucketed = cfg.arch_type == "dense"
        self._window = max_len if paged else \
            (self._cache["kv"]["k"].shape[2]
             if "kv" in self._cache else max_len)
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1,))
        # mid-prefill slot table (mixed step): slot -> {ctx, n, cursor,
        # covered, dep, salt, seq}. Always present so the legacy path's
        # _grow_and_cow/_preempt can check membership unconditionally.
        self._pf: Dict[int, dict] = {}
        self._admit_seq = 0
        # chunk-boundary cancellation hook: the AsyncDriver points this
        # at its ``abort_step`` Event; a set flag makes step() return
        # before launching the next program (watchdog recovery then runs
        # at sub-step latency instead of waiting out a full step)
        self.abort_event = None
        if self.mixed:
            self._mixed = jax.jit(self._mixed_fn, donate_argnums=(1,))
            if cfg.arch_type == "audio":
                self._encode = jax.jit(self._encode_fn,
                                       donate_argnums=(1,))

    # ------------------------------------------------------------ memory
    def kv_bytes(self) -> int:
        """GLOBAL device bytes RESIDENT in the engine's decode state (KV
        pool/rows, SSM states, cross-attention blocks; cursors and the
        page table are negligible and excluded), summed over the mesh
        when sharded. Static for the engine's lifetime — the paged pool
        is allocated up front. Step TRANSIENTS are extra: paged decode
        gathers each slot's BUCKETED table width per layer (the
        occupancy high-water bound, see layers.paged_attention), so the
        per-step scratch tracks live pages while this number is what
        lives in HBM between steps."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for key, big in self._cache.items()
                   if key not in ("pos", "ptab")
                   for leaf in jax.tree.leaves(big))

    def per_device_kv_bytes(self) -> int:
        """Resident decode-state bytes on ONE device: the head-sharded
        pool puts ~1/tp of :meth:`kv_bytes` on each of the mesh's
        devices (exactly 1/tp when every leaf's kv-head axis divides);
        equals :meth:`kv_bytes` unsharded."""
        total = 0
        for key, big in self._cache.items():
            if key in ("pos", "ptab"):
                continue
            for leaf in jax.tree.leaves(big):
                sharding = getattr(leaf, "sharding", None)
                if sharding is not None:
                    shape = sharding.shard_shape(leaf.shape)
                    total += int(np.prod(shape)) * leaf.dtype.itemsize
                else:
                    total += leaf.size * leaf.dtype.itemsize
        return total

    # --------------------------------------------------- device plumbing
    def _dev(self, x):
        """Put a host value on the engine's device(s) (replicated across
        the mesh when sharded) so jit sees one stable input sharding —
        uncommitted host arrays would leave the placement choice to the
        compiler."""
        if self.mesh is None:
            return jnp.asarray(x)
        return jax.device_put(x, NamedSharding(self.mesh, P()))

    # ------------------------------------------------------- jitted steps
    def _decode_fn(self, params, cache, tokens, pos, active, rng):
        """ONE device program advancing every slot: batched decode +
        on-device sampling + active-slot masking."""
        self.stats["decode_traces"] += 1     # Python side effect: trace-time only
        logits, cache = self.model.decode_step(params, cache, tokens, pos,
                                               self.cfg, **self._attn_kw)
        tok = sample_tokens(logits[:, -1], rng=rng,
                            temperature=self.temperature)
        tok = jnp.where(active, tok, 0)
        cache["pos"] = jnp.where(active, pos + 1, pos)
        return tok, cache

    def _prefill_fn(self, params, cache, tokens, extra, last_pos, slot,
                    pages, rng):
        """Prefill one (bucket-padded) prompt, sample its first token, and
        store the fresh per-request cache: dense leaves scatter into
        slot-table row ``slot``; with the paged layout the decoder KV
        scatters page-wise into the pool through ``pages`` instead (blocks
        the prefix cache already holds are redirected to the null page —
        their physical pages are shared and must never be rewritten).
        Retraces once per distinct padded length (= per bucket)."""
        self.stats["prefill_traces"] += 1
        if self.paged:
            # size the scratch cache to whole pages so the page scatter is
            # a static reshape (bucket padding lands in the null page)
            clen = pages_for(tokens.shape[1], self.page_size) \
                * self.page_size
        else:
            clen = self.max_len
        c1 = self.model.init_cache(self.cfg, 1, clen)
        batch = {"tokens": tokens, **extra}
        if self._bucketed:
            logits, c1 = self.model.prefill(params, batch, self.cfg, c1,
                                            last_pos=last_pos)
        else:
            logits, c1 = self.model.prefill(params, batch, self.cfg, c1)
        tok = sample_tokens(logits[0, -1], rng=rng,
                            temperature=self.temperature)
        out = {}
        for key, big in cache.items():
            if key == "pos":
                out[key] = big.at[slot].set(last_pos + 1)
            elif key == "ptab":
                out[key] = big
            elif key == "kv" and self.paged:
                out[key] = scatter_prefill_pages(big, c1[key], pages,
                                                 self.page_size)
            else:
                out[key] = jax.tree.map(
                    lambda b, o: b.at[:, slot].set(o[:, 0]), big, c1[key])
        return tok, out

    def _mixed_fn(self, params, cache, tokens, pos, slot, active, wnull,
                  rng):
        """ONE device program for a mixed token-slot batch: ``tokens`` is
        a (T, 1) column of T = ``chunk_tokens`` work items — decode
        tokens, prefill-chunk tokens and pads — each tagged with its
        ``pos`` (context position), ``slot`` (page-table row), ``active``
        (sample a token from this row's logits) and ``wnull`` (redirect
        this row's KV write to the null page: the position's KV already
        lives in shared prefix pages, or the row is padding).

        Exactness: ``decode_step`` scatters EVERY row's K/V per layer
        before the paged attention gathers, so a chunk's tokens attend to
        each other (and to a same-program donor's chunk) exactly as the
        monolithic prefill would — chunked prefill of a causal decoder is
        bit-identical. T is static and the page-table gather width is
        page-bucketed, so the program retraces once per (token budget,
        page bucket) — the bounded-trace invariant CI asserts. The
        (T, 1) layout keeps a token axis per work item, so multi-token
        speculative decode (ROADMAP #2) widens columns, not the design.
        """
        self.stats["decode_traces"] += 1    # Python side effect: trace-time only
        ptab_rows = cache["ptab"][slot]               # (T, table_width)
        view = {"kv": cache["kv"], "ptab": ptab_rows,
                "wtab": jnp.where(wnull[:, None], 0, ptab_rows)}
        if "xkv" in cache:
            view["xkv"] = jax.tree.map(lambda a: a[:, slot], cache["xkv"])
        logits, out = self.model.decode_step(params, view, tokens, pos,
                                             self.cfg, **self._attn_kw)
        tok = sample_tokens(logits[:, -1], rng=rng,
                            temperature=self.temperature)
        tok = jnp.where(active, tok, 0)
        new = {"kv": out["kv"], "pos": cache["pos"],
               "ptab": cache["ptab"]}
        if "xkv" in cache:
            new["xkv"] = cache["xkv"]
        return tok, new

    def _encode_fn(self, params, xkv, frames, slot):
        """Audio admission under the mixed step: run the encoder and park
        the per-layer cross-attention K/V in slot ``slot``'s block (the
        legacy path did this inside the monolithic prefill program).
        Takes ONLY the xkv leaves — frame shape and xkv block are fixed
        per config, so the program traces once regardless of how the
        page-table bucket evolves."""
        self.stats["encode_traces"] += 1    # Python side effect: trace-time only
        enc_out = self.model.encode(params, frames, self.cfg)
        xkvs = jax.vmap(
            lambda lp: self.model.cross_kv(lp, enc_out, self.cfg))(
            params["dec_layers"])
        return jax.tree.map(
            lambda big, new: big.at[:, slot].set(new[:, 0]), xkv, xkvs)

    def _next_rng(self):
        if self.temperature <= 0.0:
            return None
        self._rng, key = jax.random.split(self._rng)
        return key

    # --------------------------------------------------------- scheduling
    def submit(self, rid: int, prompt: np.ndarray, max_new: int, *,
               frames: Optional[np.ndarray] = None,
               images: Optional[np.ndarray] = None, priority: int = 0,
               deadline_s: Optional[float] = None):
        """Queue a request. Rejects inputs the engine can NEVER hold —
        prompts at/over ``max_len`` and, on the paged layout, requests
        whose pages can never all come free — instead of deadlocking:
        an unplaceable request would otherwise queue forever at the
        scheduler's head, and head-of-line admission means it would wedge
        everything behind it too. Two bounds, both against the TOTAL
        pool: the MINIMUM admission reservation (lazy: the prompt plus
        its first decode write; eager: the worst case up front) is what
        ``_place`` must satisfy before the first prefill, and the
        WORST-CASE context is what guarantees preemption can always make
        a lone request's extend succeed under lazy growth — the liveness
        argument in serve/scheduler.py. (Transient pressure is not a
        rejection: a request that merely has to WAIT for free pages or a
        free slot stays queued.)

        ``priority`` is the scheduler hint carried on the Request — the
        default FifoLeastProgress policy ignores it; ``scheduler=
        Priority()`` admits higher values first and preempts lower ones
        first.

        ``deadline_s`` declares an SLO: the shipped policies admit the
        nearest deadline first (and give it prefill-budget priority in
        the mixed step), and a request still QUEUED when its deadline
        passes finishes ``done=False, expired=True`` at the next step
        instead of blocking the scheduler's head.

        Returns the LIVE Request record: ``out`` grows as the engine
        decodes, which is what serve/driver.AsyncDriver streams from."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {rid}: empty prompt")
        if prompt.size >= self.max_len:
            raise ValueError(
                f"request {rid}: prompt length {prompt.size} >= max_len "
                f"{self.max_len}; the longest servable prompt is "
                f"{self.max_len - 1} tokens")
        if max_new < 1:
            raise ValueError(f"request {rid}: max_new must be >= 1")
        if self.paged:
            cap = min(prompt.size + max_new - 1, self.max_len)
            worst = pages_for(cap, self.page_size)
            if self.lazy:
                need = pages_for(min(prompt.size + 1, cap), self.page_size)
                if need > self.kv_pages:
                    raise ValueError(
                        f"request {rid}: minimum admission reservation is "
                        f"{need} KV pages ({self.page_size} tokens each) "
                        f"but the pool holds {self.kv_pages} — it could "
                        f"never be placed; raise kv_pages or shorten the "
                        f"prompt")
            if worst > self.kv_pages:
                raise ValueError(
                    f"request {rid}: worst-case context needs {worst} KV "
                    f"pages ({self.page_size} tokens each) but the pool "
                    f"holds {self.kv_pages}; raise kv_pages or lower "
                    f"prompt+max_new")
            # pre-book the worst case in the bounded-gather high-water at
            # SUBMIT time: everything accepted before the first decode
            # shares one bucket, and lazy mid-decode extends never
            # re-bucket (_sync_ptab) — only a longer request arriving
            # later can
            self._hw_blocks = max(self._hw_blocks, worst)
        if self.cfg.arch_type == "audio":
            if frames is None:
                raise ValueError(
                    f"request {rid}: {self.cfg.name} is an enc-dec arch; "
                    "submit(..., frames=(encoder_ctx, d_model)) frame "
                    "embeddings (the stubbed audio frontend's output)")
            frames = np.asarray(frames, np.float32)
            want = (self.cfg.encoder_ctx, self.cfg.d_model)
            if frames.shape != want:
                raise ValueError(
                    f"request {rid}: frames shape {frames.shape} != {want}")
        elif frames is not None:
            raise ValueError(
                f"request {rid}: frames are only meaningful for audio "
                f"archs, not {self.cfg.arch_type}")
        if self.cfg.arch_type == "vlm":
            if images is None:
                raise ValueError(
                    f"request {rid}: {self.cfg.name} is a VLM arch; "
                    "submit(..., images=(num_image_tokens, d_model)) "
                    "patch embeddings (the stubbed vision frontend's "
                    "output)")
            images = np.asarray(images, np.float32)
            want = (self.cfg.num_image_tokens, self.cfg.d_model)
            if images.shape != want:
                raise ValueError(
                    f"request {rid}: images shape {images.shape} != {want}")
        elif images is not None:
            raise ValueError(
                f"request {rid}: images are only meaningful for vlm "
                f"archs, not {self.cfg.arch_type}")
        deadline = None
        if deadline_s is not None:
            if deadline_s <= 0:
                raise ValueError(
                    f"request {rid}: deadline_s must be > 0, got "
                    f"{deadline_s}")
            deadline = time.monotonic() + float(deadline_s)
        req = Request(rid, prompt, int(max_new), frames=frames,
                      images=images, priority=int(priority),
                      deadline=deadline)
        self.queue.append(req)
        # span-tree root: the scheduler explains its ordering fields so
        # the trace records WHY admission will pick this request when
        explain = getattr(self._sched, "explain", None)
        self.tracer.req_event(
            rid, "submitted", prompt_tokens=int(prompt.size),
            max_new=int(max_new), queue_depth=len(self.queue),
            **(explain(req) if explain is not None else {}))
        return req

    def _expire_queued(self, now: float):
        """Finish every QUEUED request whose deadline has passed with
        ``done=False, expired=True`` (partial output from a preemption
        rides along) — an expired request must not wedge the scheduler's
        head-of-line contract. Active slots are never expired: their
        pages are committed and finishing them is strictly cheaper than
        wasting the work."""
        if not any(r.deadline is not None for r in self.queue):
            return
        kept: Deque[Request] = deque()
        for req in self.queue:
            if req.deadline is not None and now > req.deadline:
                req.expired = True
                self.finished[req.rid] = req
                self.stats["expired"] += 1
                self.tracer.finish_request(req.rid, "expired",
                                           tokens=len(req.out))
            else:
                kept.append(req)
        self.queue = kept

    def _free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if self.active[s] is None:
                return s
        return None

    # ------------------------------------------------- paged bookkeeping
    def _sync_ptab(self):
        """Refresh the DEVICE page table from the host mirror, clipped to
        the power-of-two bucket of the live page high-water mark — the
        bounded-gather contract of layers.paged_attention. The mark is
        the max of the allocator's per-owner page high-water and every
        admitted request's WORST-CASE reservation (``_hw_blocks``):
        under eager reservation the two coincide; under lazy growth the
        worst case is pre-booked at admission so mid-decode extends
        never cross a bucket — the one-decode-trace invariant survives
        laziness, and the bound still only re-buckets when a LONGER
        request is admitted. Every live slot's pages fit the bucket (the
        mark dominates every reservation), so no table entry is
        truncated; retired slots' frozen cursors beyond it resolve to
        the null page via the table-width clip in
        kvcache.write_kv_paged."""
        w = page_bucket(max(1, self._hw_blocks,
                            self._alloc.peak_owner_pages), cap=self._pps)
        if w != self._gather:
            self._gather = w
            self._ptab_dirty = True
        if self._ptab_dirty:
            self._cache["ptab"] = self._dev(
                np.ascontiguousarray(self._ptab[:, :w], np.int32))
            self._ptab_dirty = False

    def _note_pool(self):
        used = self._alloc.pages_in_use
        self.stats["pages_in_use"] = used
        if used > self.stats["peak_pages"]:
            self.stats["peak_pages"] = used
        if self._prefix is not None:
            self.stats["prefix_hit_blocks"] = self._prefix.hit_blocks
            self.stats["prefix_miss_blocks"] = self._prefix.miss_blocks
            self.stats["prefix_tail_hits"] = self._prefix.tail_hits

    def _salt(self, req: Request, ctx: np.ndarray):
        """Prefix-cache namespace: blocks are only portable where causal
        KV depends on the prefix alone — enc-dec KV also depends on the
        frames, MoE capacity routing on the whole sequence, so those key
        coarser (identical frames / identical full context). Memoized on
        the request: frames never change, and ``ctx`` (prompt + emitted)
        is uniquely determined by its length over a request's lifetime."""
        if self.cfg.arch_type == "moe":
            if req.salt_cache is None or req.salt_cache[0] != len(ctx):
                req.salt_cache = (len(ctx), ("moe-ctx", hashlib.sha1(
                    np.ascontiguousarray(ctx).tobytes()).hexdigest()))
            return req.salt_cache[1]
        if req.frames is not None:
            if req.salt_cache is None:
                req.salt_cache = (0, ("frames", hashlib.sha1(
                    np.ascontiguousarray(req.frames).tobytes()).hexdigest()))
            return req.salt_cache[1]
        return None

    def _place(self, s: int, req: Request, ctx: np.ndarray):
        """Reserve slot ``s``'s pages for admission: prefix-cache match ->
        adopt shared pages, then draw fresh ones (lazy: prompt + first
        decode page; otherwise the worst case), evicting cold prefix
        blocks when the free-list is short. Returns (block-ordered pages,
        shared head count) or (None, 0) on backpressure."""
        n = len(ctx)
        if self.lazy:
            # the context plus its first decode write — clamped to the
            # request's remaining worst case, which submit() validated
            # against the pool: a request finishing AT admission
            # (max_new reached on the prefill token) never writes a
            # decode token, so demanding its +1 page could deadlock a
            # pool the worst case fits
            reserve = min(n + 1, n + req.max_new - len(req.out) - 1,
                          self.max_len)
        else:
            reserve = min(n + req.max_new - len(req.out) - 1, self.max_len)
        shared: List[int] = []
        salt = None
        if self._prefix is not None:
            salt = self._salt(req, ctx)
            # partial-tail adoption forces a copy-on-write at the first
            # decode write; only lazy mode has the mid-decode alloc path
            # (and its reclaim ladder) to pay for that copy.
            full_pages, tail_page, _ = self._prefix.match(
                ctx, salt=salt, want_tail=self.lazy)
            shared = list(full_pages)
            if tail_page is not None:
                shared.append(tail_page)
        got = self._alloc.alloc(s, reserve, shared=shared)
        if got is None and self._prefix is not None:
            need = (pages_for(reserve, self.page_size) - len(shared)
                    - self._alloc.free_pages)
            keep = frozenset(shared)
            # only spend cached blocks when evicting can actually cover
            # the shortfall — otherwise the request waits for retirements
            # anyway and the flushed blocks would have bought nothing
            if 0 < need <= self._prefix.evictable_pages(keep=keep):
                while need > 0 and self._prefix.evict_one(keep=keep):
                    self.stats["prefix_evictions"] += 1
                    need -= 1
                got = self._alloc.alloc(s, reserve, shared=shared)
        if got is None:
            return None, 0
        if self._prefix is not None:
            # count reuse on SUCCESSFUL adoption only (a backpressured
            # head-of-line request re-matches every step)
            full = len(shared) - (1 if tail_page is not None else 0)
            self._prefix.hit_blocks += full
            self._prefix.miss_blocks += n // self.page_size - full
            if tail_page is not None:
                self._prefix.tail_hits += 1
            # register this context's freshly written full blocks so the
            # NEXT request (or this one's re-admission) shares them
            self._prefix.insert(ctx, got, salt=salt)
        self._note_pool()
        return got, len(shared)

    def _admit(self):
        while True:
            qi = self._sched.next_index(self.queue)
            if qi is None:
                return
            s = self._free_slot()
            if s is None:
                return
            req = self.queue[qi]
            # a preempted request resumes by prefilling prompt + emitted
            ctx = req.prompt if not req.out else np.concatenate(
                [req.prompt, np.asarray(req.out, np.int32)])
            n = len(ctx)
            blen = prefill_bucket(n, cap=self._window) if self._bucketed \
                else n
            pages = None
            if self.paged:
                got, n_shared = self._place(s, req, ctx)
                if got is None:
                    # head-of-line: WAIT for retirements/evictions instead
                    # of admitting around the scheduler's pick
                    return
                self._ptab[s] = 0
                self._ptab[s, :len(got)] = got
                self._ptab_dirty = True
                npb = pages_for(blen, self.page_size)
                page_vec = np.zeros(npb, np.int64)
                m = min(npb, len(got))
                page_vec[:m] = got[:m]
                # shared head pages already hold this prefix's KV — the
                # prefill scatter must not rewrite pages other slots read;
                # redirect those blocks to the null page
                page_vec[:min(n_shared, npb)] = 0
                pages = self._dev(page_vec.astype(np.int32))
            if qi == 0:
                self.queue.popleft()
            else:
                del self.queue[qi]
            self.tracer.req_event(req.rid, "admitted", slot=s,
                                  ctx_tokens=n, resumed=bool(req.out))
            if self.paged:
                self._sync_ptab()
            padded = np.zeros(blen, np.int32)
            padded[:n] = ctx
            extra = {}
            if req.frames is not None:
                extra["frames"] = self._dev(req.frames[None])
            if req.images is not None:
                extra["image_embeds"] = self._dev(req.images[None])
            with self._ctx():
                tok, self._cache = self._prefill(
                    self.params, self._cache, self._dev(padded[None]), extra,
                    self._dev(np.int32(n - 1)), self._dev(np.int32(s)),
                    pages, self._next_rng())
            self.stats["prefills"] += 1
            self.stats["decode_tokens"] += 1
            tok = int(tok)
            req.out.append(tok)
            self.tracer.req_tokens(req.rid, 1)
            if req.first_tok_t is None:
                req.first_tok_t = time.monotonic()
                self.tracer.req_event(req.rid, "first_token",
                                      prefill_tokens=n)
            self._pos[s] = n
            self._last[s] = tok
            # honor max_new / EOS / capacity on the PREFILL-sampled token:
            # a request that is already complete never occupies a slot (or
            # pages), so output length is exactly min(max_new,
            # tokens-until-EOS)
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if len(req.out) >= req.max_new or hit_eos or n >= self.max_len:
                req.done = True
                self.finished[req.rid] = req
                self.tracer.finish_request(req.rid, "completed",
                                           tokens=len(req.out))
                if self.paged:
                    self._release_pages(s)
            else:
                self.active[s] = req

    # ------------------------------------------- mixed (chunked) admission
    def _place_mixed(self, s: int, req: Request, ctx: np.ndarray):
        """Reserve slot ``s``'s pages for MIXED admission — like
        :meth:`_place` but prefill happens later, chunk by chunk, so the
        radix tree is NOT updated here (the step loop inserts
        progressively as the cursor passes block boundaries) and the
        match can additionally adopt pages from a slot still
        MID-PREFILL over the same context (the tree only knows blocks a
        donor's cursor already passed). Returns ``(pages, covered,
        dep)``: ``covered`` counts context tokens whose KV this slot
        must NOT rewrite (shared pages), ``dep`` is ``(donor_slot,
        needed_tokens)`` when some of that coverage is still being
        written by a donor — the budget packer holds this slot's chunks
        until the donor's planned cursor reaches ``needed_tokens``.
        ``(None, 0, None)`` on backpressure."""
        n = len(ctx)
        if self.lazy:
            reserve = min(n + 1, n + req.max_new - len(req.out) - 1,
                          self.max_len)
        else:
            reserve = min(n + req.max_new - len(req.out) - 1, self.max_len)
        ps = self.page_size
        shared: List[int] = []
        covered = 0
        dep = None
        salt = None
        tail_page = None
        if self._prefix is not None:
            salt = self._salt(req, ctx)
            full_pages, tail_page, _ = self._prefix.match(
                ctx, salt=salt, want_tail=self.lazy)
            shared = list(full_pages)
            covered = len(full_pages) * ps
            if tail_page is not None:
                # a matched tail block covers the ENTIRE remaining
                # context (prefix.match's contract), so nothing is left
                # to prefill-write; CoW duplicates it before the first
                # decode write (lazy-only, as on the legacy path)
                shared.append(tail_page)
                covered = n
            elif covered < n:
                # in-flight donor: a mid-prefill slot over the same
                # context extends coverage beyond the tree
                for d, st in self._pf.items():
                    if st["salt"] != salt:
                        continue
                    dctx = st["ctx"]
                    lim = min(n, len(dctx)) // ps * ps
                    m = covered
                    while m + ps <= lim and np.array_equal(
                            ctx[m:m + ps], dctx[m:m + ps]):
                        m += ps
                    if m > covered:
                        dpages = self._alloc.pages_of(d)
                        shared.extend(dpages[covered // ps:m // ps])
                        dep = (d, m)
                        covered = m
                        break
        got = self._alloc.alloc(s, reserve, shared=shared)
        if got is None and self._prefix is not None:
            need = (pages_for(reserve, ps) - len(shared)
                    - self._alloc.free_pages)
            keep = frozenset(shared)
            if 0 < need <= self._prefix.evictable_pages(keep=keep):
                while need > 0 and self._prefix.evict_one(keep=keep):
                    self.stats["prefix_evictions"] += 1
                    need -= 1
                got = self._alloc.alloc(s, reserve, shared=shared)
        if got is None:
            return None, 0, None
        if self._prefix is not None:
            # every covered block — tree hit or in-flight adoption — is
            # prefill work this request skips
            full = covered // ps if tail_page is None else len(full_pages)
            self._prefix.hit_blocks += full
            self._prefix.miss_blocks += n // ps - full
            if tail_page is not None:
                self._prefix.tail_hits += 1
        self._note_pool()
        return got, covered, dep

    def _admit_mixed(self):
        """Mixed-step admission: place pages and ENQUEUE the prefill work
        (no device call here — the step loop drains it chunk by chunk
        through the one mixed program). The slot is active immediately;
        its first token is sampled on the chunk containing the final
        prompt position."""
        while True:
            qi = self._sched.next_index(self.queue)
            if qi is None:
                return
            s = self._free_slot()
            if s is None:
                return
            req = self.queue[qi]
            ctx = req.prompt if not req.out else np.concatenate(
                [req.prompt, np.asarray(req.out, np.int32)])
            n = len(ctx)
            got, covered, dep = self._place_mixed(s, req, ctx)
            if got is None:
                return
            self._ptab[s] = 0
            self._ptab[s, :len(got)] = got
            self._ptab_dirty = True
            if qi == 0:
                self.queue.popleft()
            else:
                del self.queue[qi]
            if req.frames is not None:
                with self._ctx():
                    self._cache["xkv"] = self._encode(
                        self.params, self._cache["xkv"],
                        self._dev(req.frames[None]),
                        self._dev(np.int32(s)))
            self.active[s] = req
            self._pos[s] = 0
            self._last[s] = 0
            self.tracer.req_event(req.rid, "admitted", slot=s,
                                  ctx_tokens=n, covered=int(covered),
                                  resumed=bool(req.out))
            # cursor = next context position to compute; covered KV is
            # skipped EXCEPT the final prompt token, which must run for
            # its first-token logits (its write goes to the null page)
            self._pf[s] = {
                "ctx": ctx, "n": n, "cursor": int(min(covered, n - 1)),
                "covered": int(covered), "dep": dep,
                "salt": (self._salt(req, ctx)
                         if self._prefix is not None else None),
                "seq": self._admit_seq}
            self._admit_seq += 1

    def _release_pages(self, s: int):
        """Drop slot ``s``'s page references (shared prefix pages stay
        live for their other holders / the prefix cache) and point its
        table row at the null page so any frozen-cursor write lands in
        scratch."""
        self._alloc.free(s)
        self._ptab[s] = 0
        self._ptab_dirty = True
        self._note_pool()

    def _retire(self, s: int):
        req = self.active[s]
        req.done = True
        self.finished[req.rid] = req
        self.tracer.finish_request(req.rid, "completed",
                                   tokens=len(req.out))
        self.active[s] = None
        if self.paged:
            self._release_pages(s)

    # -------------------------------------------- lazy growth + CoW + preempt
    def _preempt(self, s: int):
        """Evict slot ``s`` mid-decode: release its pages (prefix pages
        merely drop a reference and usually stay cached) and requeue the
        request, partial output intact, for re-prefill. A MID-PREFILL
        victim (mixed step) additionally cascades to any dependent slot
        that adopted its pages beyond what its cursor wrote — that KV
        will never exist, so the dependent re-prefills too."""
        req = self.active[s]
        st = self._pf.pop(s, None)
        self.active[s] = None
        if self.paged:
            self._release_pages(s)
        self._sched.requeue(self.queue, req)
        self.stats["preemptions"] += 1
        self.tracer.req_preempted(req.rid, slot=s, tokens=len(req.out),
                                  mid_prefill=st is not None)
        if st is not None:
            for d, dst in list(self._pf.items()):
                if dst["dep"] is not None and dst["dep"][0] == s \
                        and st["cursor"] < dst["dep"][1]:
                    self._preempt(d)

    def preempt(self, s: int):
        """Public cancel-and-requeue of slot ``s`` (any KV layout): the
        watchdog's recovery path (serve/driver.py). The request keeps its
        partial output and resumes by re-prefill — greedy decode is
        bit-identical to the uninterrupted run."""
        if not 0 <= s < self.slots or self.active[s] is None:
            raise ValueError(f"slot {s} holds no active request")
        self._preempt(s)

    def _reclaim_one(self, needy: int) -> bool:
        """Free pool capacity for slot ``needy``: evict one cold prefix
        block if possible, else preempt the scheduler's victim. Returns
        False when ``needy`` itself was preempted or nothing is left to
        reclaim (the caller must skip the slot this step)."""
        if self._prefix is not None and self._prefix.evict_one():
            self.stats["prefix_evictions"] += 1
            return True
        victims = [(t, len(self.active[t].out), self.active[t].priority)
                   for t in range(self.slots) if self.active[t] is not None]
        if not victims:
            return False
        v = self._sched.pick_victim(victims)
        self._preempt(v)
        return v != needy

    def _extend_reclaiming(self, s: int, n_tokens: int):
        """allocator.extend with the reclaim ladder. Returns the fresh
        pages, or None when slot ``s`` was preempted to satisfy itself."""
        while True:
            fresh = self._alloc.extend(s, n_tokens)
            if fresh is not None:
                self._note_pool()
                return fresh
            if not self._reclaim_one(s):
                return None

    def _cow_reclaiming(self, s: int, blk: int) -> bool:
        """Copy-on-write slot ``s``'s page at ``blk`` (allocator swap +
        device page copy), reclaiming if no page is free. Returns False
        when slot ``s`` was preempted instead."""
        while True:
            old = self._alloc.pages_of(s)[blk]
            new = self._alloc.cow(s, blk)
            if new is not None:
                if new != old:
                    with self._ctx():
                        self._cache["kv"] = self._copy_page(
                            self._cache["kv"], self._dev(np.int32(old)),
                            self._dev(np.int32(new)))
                    self._ptab[s, blk] = new
                    self._ptab_dirty = True
                    self.stats["cow_copies"] += 1
                    self._note_pool()
                return True
            if not self._reclaim_one(s):
                return False

    def _grow_and_cow(self):
        """Before the batched decode writes at each slot's cursor: grow
        lazy reservations across page boundaries and copy-on-write any
        write-target page that is still shared. Either can preempt slots
        (including the needy one) when the pool runs dry."""
        ps = self.page_size
        for s in range(self.slots):
            # mid-prefill slots (mixed step) neither decode-write nor
            # grow this step — and their shared head blocks must NOT be
            # CoW'd (pos is still 0, but the block is a prefix hit)
            if self.active[s] is None or s in self._pf:
                continue
            pos = int(self._pos[s])
            if self.lazy and \
                    pages_for(pos + 1, ps) > len(self._alloc.pages_of(s)):
                fresh = self._extend_reclaiming(s, pos + 1)
                if fresh is None:
                    continue                  # s was preempted
                w = len(self._alloc.pages_of(s))
                self._ptab[s, w - len(fresh):w] = fresh
                self._ptab_dirty = True
            own = self._alloc.pages_of(s)
            blk = pos // ps
            if blk < len(own) and self._alloc.refcount(own[blk]) > 1:
                self._cow_reclaiming(s, blk)

    # ------------------------------------------------ speculative decode
    def _propose_drafts(self, decode_slots):
        """Ask the drafter for up to ``spec.k`` continuation tokens per
        decoding slot and reserve the pages their KV writes need.

        ``k_s`` is clamped so every drafted position stays inside the
        request's own remaining budget (``max_new``) and the context cap
        — which keeps the write positions inside the worst-case
        reservation submit() pre-booked in ``_hw_blocks``, so drafting
        NEVER re-buckets the bounded gather (trace count stays one per
        (token budget, page bucket), speculation on or off). Under lazy
        growth the reservation is extended to cover the draft writes up
        front with a PLAIN extend — speculation is opportunistic and
        must never evict prefix blocks or preempt a neighbour to place
        a guess, so a dry pool just truncates the draft (the rejection
        path returns these pages via ``PageAllocator.rollback``). Eager
        reservations already hold the worst case and are never touched.
        """
        drafts = {}
        for s in decode_slots:
            req = self.active[s]
            P = int(self._pos[s])
            k_s = min(self.spec.k, req.max_new - len(req.out) - 1,
                      self.max_len - 1 - P)
            if k_s < 1:
                continue
            ctx = np.concatenate(
                [req.prompt, np.asarray(req.out, np.int64)])
            d = np.asarray(self._drafter.propose(ctx, k_s),
                           np.int64).reshape(-1)[:k_s]
            if len(d) and self.lazy:
                if self._alloc.extend(s, P + 1 + len(d)) is None:
                    # pool dry: keep only the drafts whose writes fit
                    # the pages already held (possibly none)
                    room = (len(self._alloc.pages_of(s)) * self.page_size
                            - P - 1)
                    d = d[:max(room, 0)]
                    if len(d):
                        self._alloc.extend(s, P + 1 + len(d))
                if len(d):
                    own = self._alloc.pages_of(s)
                    self._ptab[s, :len(own)] = own
                    self._ptab_dirty = True
                    self._note_pool()
            if len(d):
                drafts[s] = d
        return drafts

    def release_prefix_cache(self) -> int:
        """Flush every prefix block no live request still shares, freeing
        their pages. Returns the number of blocks evicted."""
        if self._prefix is None:
            return 0
        n = self._prefix.flush()
        self.stats["prefix_evictions"] += n
        self._note_pool()
        return n

    # -------------------------------------------------------------- serve
    def step(self) -> int:
        """Advance the engine by one step. MIXED engines (the default on
        the paged layout) run ONE token-slot program covering every
        active slot's decode token plus prefill chunks inside the
        ``chunk_tokens`` budget (:meth:`_step_mixed`); legacy engines
        admit-with-synchronous-prefill then run the batched decode.
        Returns the number of tokens produced this step — the
        AsyncDriver's streaming signal. Step timing lands in ``stats``:
        ``step_count`` and ``wall_time_s`` cover every call, and
        ``tokens_per_s_ewma`` smooths the produced-tokens rate (alpha
        0.2) for the DP router's latency-aware routing."""
        if self.mixed:
            return self._step_mixed()
        t0 = time.perf_counter()
        before = self.stats["decode_tokens"]
        tr = self.tracer.begin_step(self.stats["step_count"])
        self._expire_queued(time.monotonic())
        tr.lap("bookkeeping")
        self._admit()
        # the legacy path prefills synchronously inside admission (its
        # own device program), so it gets its own phase label instead of
        # hiding inside bookkeeping
        tr.lap("admit")
        if self.paged and (self.lazy or self._prefix is not None):
            self._grow_and_cow()
        tr.lap("bookkeeping")
        mask = np.array([r is not None for r in self.active])
        if mask.any():
            if self.paged:
                self._sync_ptab()
            tr.lap("pack")
            with self._ctx():
                tok, self._cache = self._decode(
                    self.params, self._cache,
                    self._dev(self._last[:, None].astype(np.int32)),
                    self._dev(self._pos.astype(np.int32)), self._dev(mask),
                    self._next_rng())
            tr.lap("dispatch")
            self.stats["decode_steps"] += 1
            self.stats["decode_slot_steps"] += int(mask.sum())
            toks = np.asarray(tok)
            tr.lap("sync")
            for s in range(self.slots):
                req = self.active[s]
                if req is None:
                    continue
                t = int(toks[s])
                req.out.append(t)
                self._pos[s] += 1
                self._last[s] = t
                self.stats["decode_tokens"] += 1
                tr.note_decode(s, req.rid, 1)
                self.tracer.req_tokens(req.rid, 1)
                self.tracer.req_detail(req.rid, "decode", slot=s,
                                       pos=int(self._pos[s]))
                if self._prefix is not None and \
                        self._pos[s] % self.page_size == 0:
                    self._register_decode_block(s, req)
                hit_eos = self.eos_id is not None and t == self.eos_id
                if len(req.out) >= req.max_new or hit_eos or \
                        self._pos[s] >= self.max_len:
                    self._retire(s)
        return self._finish_step(t0, before, tr)

    def _finish_step(self, t0: float, before: int, tr=NULL_STEP) -> int:
        """Shared step epilogue: token count + timing telemetry, and the
        step's trace record (residual time folds into bookkeeping so the
        phase laps partition the whole step)."""
        produced = self.stats["decode_tokens"] - before
        tr.lap("bookkeeping")
        self.tracer.end_step(tr, produced)
        dt = time.perf_counter() - t0
        self.stats["step_count"] += 1
        self.stats["wall_time_s"] += dt
        if produced and dt > 0:
            rate = produced / dt
            ewma = self.stats["tokens_per_s_ewma"]
            self.stats["tokens_per_s_ewma"] = \
                rate if ewma <= 0 else 0.8 * ewma + 0.2 * rate
        return produced

    # ---- observability surface (delegates to the tracer) -------------

    def trace(self) -> dict:
        """Chrome ``trace_event`` JSON object for this engine's tracer."""
        return chrome_trace([self.tracer])

    def export_trace(self, path: str) -> dict:
        """Write the Chrome/Perfetto trace JSON to ``path``."""
        return export_chrome_trace(path, [self.tracer])

    def flight(self, last: int | None = None) -> dict:
        """Flight-recorder snapshot (recent step records + spans)."""
        return self.tracer.flight(last)

    def _step_mixed(self) -> int:
        """One MIXED token-slot step (the tentpole refactor): expire
        overdue queued requests, admit into free slots (pages only — no
        synchronous prefill), then fill the ``chunk_tokens`` budget with
        every decoding slot's next token FIRST and prefill chunks of
        mid-prefill slots after (scheduler's ``prefill_key`` order,
        nearest deadline first), and run the whole batch as ONE device
        program. A slot's first token is sampled on the chunk containing
        its final prompt position; admission runs again at the END so a
        request finishing at admission frees its slot for the same-step
        queue (matching the legacy path's same-step admission cadence).
        """
        t0 = time.perf_counter()
        before = self.stats["decode_tokens"]
        tr = self.tracer.begin_step(self.stats["step_count"])
        abort = self.abort_event
        if abort is not None and abort.is_set():
            # chunk-boundary cancellation (watchdog): skip launching this
            # step's program entirely — control returns to the driver at
            # sub-step latency and recovery requeues the slots
            return self._finish_step(t0, before, tr)
        self._expire_queued(time.monotonic())
        self._admit_mixed()
        if self.lazy or self._prefix is not None:
            self._grow_and_cow()
        tr.lap("bookkeeping")
        # clear satisfied dependencies: the donor finished its prefill
        # (left _pf with full coverage) or its cursor passed the needed
        # point; a donor preempted EARLIER already cascaded (see
        # _preempt), so absence means satisfied
        for st in self._pf.values():
            if st["dep"] is not None:
                d, needed = st["dep"]
                dst = self._pf.get(d)
                if dst is None or dst["cursor"] >= needed:
                    st["dep"] = None
        decode_slots = [s for s in range(self.slots)
                        if self.active[s] is not None and s not in self._pf]
        drafts = self._propose_drafts(decode_slots) \
            if self._drafter is not None else {}
        tr.lap("draft")
        pkey = getattr(self._sched, "prefill_key", None)
        items = sorted(
            self._pf.items(),
            key=lambda kv: ((pkey(self.active[kv[0]])
                             if pkey is not None else ()), kv[1]["seq"]))
        allot = pack_token_budget(
            self.chunk_tokens,
            [1 + len(drafts.get(s, ())) for s in decode_slots],
            [{"slot": s, "cursor": st["cursor"], "n": st["n"],
              "dep": st["dep"]} for s, st in items])
        if not decode_slots and not allot:
            self._admit_mixed()
            return self._finish_step(t0, before, tr)
        T = self.chunk_tokens
        tokens = np.zeros((T, 1), np.int32)
        pos = np.zeros(T, np.int32)
        slot_v = np.zeros(T, np.int32)
        active = np.zeros(T, bool)
        wnull = np.ones(T, bool)      # pads write to the null page
        r = 0
        base_row: Dict[int, int] = {}
        draft_rows: Dict[int, List[int]] = {}
        for s in decode_slots:
            tokens[r, 0] = self._last[s]
            pos[r] = self._pos[s]
            slot_v[r] = s
            active[r] = True
            wnull[r] = False
            base_row[s] = r
            r += 1
            # speculative draft rows: same slot, consecutive positions.
            # Draft row i carries drafted token d[i] at position P+1+i;
            # its logits are the verifier's token for position P+2+i —
            # valid exactly when d[0..i] all matched (the accept loop's
            # prefix rule). KV order is exact: _mixed_fn scatters EVERY
            # row's K/V before the attention gathers, and a row at
            # position p attends to kv_len p+1, so the base row never
            # sees draft KV while draft row i sees the base write and
            # drafts 0..i-1.
            for i, t in enumerate(drafts.get(s, ())):
                tokens[r, 0] = t
                pos[r] = self._pos[s] + 1 + i
                slot_v[r] = s
                active[r] = True
                wnull[r] = False
                draft_rows.setdefault(s, []).append(r)
                r += 1
        emit_row: Dict[int, int] = {}
        for s, start, count in allot:
            st = self._pf[s]
            ctx, cov, last = st["ctx"], st["covered"], st["n"] - 1
            for p in range(start, start + count):
                tokens[r, 0] = ctx[p]
                pos[r] = p
                slot_v[r] = s
                wnull[r] = p < cov
                if p == last:
                    active[r] = True
                    emit_row[s] = r
                r += 1
        if abort is not None and abort.is_set():
            # the watchdog fired while admission/encode/grow ran: yield
            # at this chunk boundary instead of launching the program
            return self._finish_step(t0, before, tr)
        self._sync_ptab()
        tr.lap("pack")
        with self._ctx():
            tok, self._cache = self._mixed(
                self.params, self._cache, self._dev(tokens),
                self._dev(pos), self._dev(slot_v), self._dev(active),
                self._dev(wnull), self._next_rng())
        tr.lap("dispatch")
        toks = np.asarray(tok)
        tr.lap("sync")
        if decode_slots:
            self.stats["decode_steps"] += 1
            self.stats["decode_slot_steps"] += len(decode_slots)
        for s in decode_slots:
            req = self.active[s]
            d = drafts.get(s, ())
            # greedy acceptance: the base row's argmax is ALWAYS the true
            # next token (bit-identical to non-speculative decode); draft
            # i's logits are valid iff d[0..i] matched the chain so far,
            # so accept the longest matching prefix plus the verifier's
            # one bonus token after it.
            accepted = [int(toks[base_row[s]])]
            m = 0
            while m < len(d) and int(d[m]) == accepted[-1]:
                accepted.append(int(toks[draft_rows[s][m]]))
                m += 1
            self.stats["spec_drafted"] += len(d)
            self.stats["spec_accepted"] += m
            # consume token-by-token, exactly mirroring the non-spec
            # epilogue: max_new / EOS / capacity stop the chain mid-draft
            # (output length stays min(max_new, tokens-until-EOS)).
            emitted = 0
            retired = False
            for t in accepted:
                req.out.append(t)
                self._pos[s] += 1
                self._last[s] = t
                self.stats["decode_tokens"] += 1
                # count BEFORE a possible retire: finish_request seals the
                # span, so the token total must already be up to date
                self.tracer.req_tokens(req.rid, 1)
                emitted += 1
                if self._prefix is not None and \
                        self._pos[s] % self.page_size == 0:
                    self._register_decode_block(s, req)
                hit_eos = self.eos_id is not None and t == self.eos_id
                if len(req.out) >= req.max_new or hit_eos or \
                        self._pos[s] >= self.max_len:
                    # detail event first — retiring seals the span tree
                    self.tracer.req_detail(req.rid, "decode", slot=s,
                                           tokens=emitted,
                                           drafted=len(d), accepted=m)
                    self._retire(s)
                    retired = True
                    break
            if not retired:
                self.tracer.req_detail(req.rid, "decode", slot=s,
                                       tokens=emitted, drafted=len(d),
                                       accepted=m)
            tr.note_decode(s, req.rid, emitted, drafted=len(d), accepted=m)
            if self.lazy and len(d) and self.active[s] is req:
                # rejection rollback: drop draft pages beyond the
                # accepted cursor (retired slots already freed all pages)
                # and restore the lazy invariant _len == pos; the freed
                # tail is always this step's extend-fresh private pages,
                # so shared/prefix pages are never touched. The stale KV
                # inside kept pages is invisible (kv_len masks by pos)
                # and overwritten before the cursor passes it.
                dropped = self._alloc.rollback(s, int(self._pos[s]))
                if dropped:
                    w = len(self._alloc.pages_of(s))
                    self._ptab[s, w:w + len(dropped)] = 0
                    self._ptab_dirty = True
                self._note_pool()
        ps = self.page_size
        for s, start, count in allot:
            st = self._pf[s]
            st["cursor"] = start + count
            self.stats["prefill_chunk_tokens"] += count
            rid = self.active[s].rid
            tr.note_chunk(s, rid, start, count)
            self.tracer.req_chunk_tokens(rid, count)
            self.tracer.req_detail(rid, "prefill_chunk", slot=s,
                                   start=start, count=count)
            if self._prefix is not None:
                # progressive registration: only blocks the cursor has
                # fully passed — a later request (or a preemption
                # cascade) must never adopt an unwritten block
                aligned = st["cursor"] // ps * ps
                if aligned > 0:
                    self._prefix.insert(st["ctx"][:aligned],
                                        self._alloc.pages_of(s),
                                        salt=st["salt"])
            if st["cursor"] > st["n"] - 1:
                # final chunk ran the last prompt position: emit the
                # first token and flip the slot to decoding
                del self._pf[s]
                req = self.active[s]
                t = int(toks[emit_row[s]])
                self.stats["prefills"] += 1
                self.stats["decode_tokens"] += 1
                req.out.append(t)
                self.tracer.req_tokens(req.rid, 1)
                if req.first_tok_t is None:
                    req.first_tok_t = time.monotonic()
                    self.tracer.req_event(req.rid, "first_token",
                                          prefill_tokens=int(st["n"]))
                self._pos[s] = st["n"]
                self._last[s] = t
                hit_eos = self.eos_id is not None and t == self.eos_id
                if len(req.out) >= req.max_new or hit_eos or \
                        st["n"] >= self.max_len:
                    req.done = True
                    self.finished[req.rid] = req
                    self.active[s] = None
                    self._release_pages(s)
                    self.tracer.finish_request(req.rid, "completed",
                                               tokens=len(req.out))
        self._admit_mixed()
        return self._finish_step(t0, before, tr)

    def _register_decode_block(self, s: int, req: Request):
        """DECODE-GENERATED prefix registration: slot ``s``'s cursor just
        crossed a page boundary, so the page holding the latest block is
        complete — register it in the radix tree under the same per-arch
        exactness salt the prompt path uses, and a repeat continuation
        (or this request's own post-preemption re-prefill) adopts it
        instead of recomputing. Only the WRITTEN context counts: KV
        exists for positions 0..pos-1 = prompt + out[:-1] (the newest
        sampled token is the next step's input)."""
        ctx = np.concatenate([req.prompt, np.asarray(req.out[:-1],
                                                     np.int32)])
        self.stats["prefix_decode_blocks"] += self._prefix.insert(
            ctx, self._alloc.pages_of(s), salt=self._salt(req, ctx))

    def reset_stats(self):
        """Zero the telemetry counters so benches measure steady state
        instead of since-construction — EXCEPT the trace counters
        (``decode_traces``/``prefill_traces``): those assert program
        identity over the engine's lifetime (the one-trace-per-bucket CI
        property) and stay monotonic. Pool gauges restart from the
        current occupancy; the prefix cache's hit/miss counters restart
        from zero."""
        keep = ("decode_traces", "prefill_traces", "encode_traces",
                "decode_backend")
        for k, v in self.stats.items():
            if k not in keep:
                self.stats[k] = 0.0 if isinstance(v, float) else 0
        if self._prefix is not None:
            self._prefix.hit_blocks = 0
            self._prefix.miss_blocks = 0
            self._prefix.tail_hits = 0
        if self.paged:
            self._note_pool()
            self.stats["peak_pages"] = self.stats["pages_in_use"]

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Serve until the queue and slot table drain (or ``max_steps``).

        Returns every submitted request's record: completed ones with
        ``done=True``, still-active ones with their partial output and
        still-queued ones with ``out == []`` (both ``done=False``) when
        ``max_steps`` is exhausted — nothing vanishes."""
        steps = 0
        while self.busy() and steps < max_steps:
            self.step()
            steps += 1
        return self.results()

    def busy(self) -> bool:
        """True while any request is queued or mid-decode."""
        return bool(self.queue) or any(r is not None for r in self.active)

    def results(self) -> Dict[int, Request]:
        """Every submitted request's record so far: finished, active
        (partial ``out``) and queued (``out == []``) — nothing vanishes.
        Shared by :meth:`run` and serve/parallel.ReplicaRouter."""
        results = dict(self.finished)
        for req in list(self.active) + list(self.queue):
            if req is not None:
                results[req.rid] = req
        return results
