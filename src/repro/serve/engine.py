"""Serving engine: continuous batching over ONE batched decode step.

A fixed-size slot table (the batch) holds independent requests at
different generation depths. The whole table advances with a SINGLE
jitted decode call per engine step: every cache leaf is stacked
``(layers, slots, ...)``, positions are a per-slot vector, and
``decode_step`` scatters each row's new KV at its own cursor
(``cache["k"].at[arange(slots), pos]``) while the attention mask keeps
each row inside its own valid prefix. Finished/empty slots are masked on
device — their sampled tokens are zeroed and their cursors frozen — so
device dispatch per step is O(1) in the number of active slots, not
O(active_slots) as in the per-slot loop this replaces.

Admission fills free slots from a FIFO queue between steps (the standard
orca/vllm-style outer loop, minus paged KV). Prefill pads prompts to
power-of-two buckets (serve/step.prefill_bucket) so XLA retraces at most
log2(max_len) prefill shapes instead of one per distinct prompt length;
the padded rows are causally invisible and their cache entries stay
masked until decode overwrites them. Sampling (greedy or temperature)
runs on device inside the same jitted step (serve/sampling.py).

Caveats: MoE archs skip prompt bucketing, and their batched decode can
differ from single-request decode — capacity-based expert routing couples
rows of a batch (pad/neighbour tokens consume expert capacity). Dense,
SSM and hybrid archs are row-independent and token-identical to
sequential decoding.

``engine.stats`` counts device calls AND traces (``decode_traces`` /
``prefill_traces`` increment only while tracing), so tests can assert the
one-program property directly.

Preferred construction: ``repro.api.Session.serve(slots=..., max_len=...)``
— the Session supplies the params (freshly initialised, restored from a
checkpoint, or just trained) so callers never thread param trees by hand.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model
from repro.serve.sampling import sample_tokens
from repro.serve.step import prefill_bucket

#: archs the token-only engine can serve (audio/VLM need their stubbed
#: frontends wired into prefill; see serve/step.py).
TOKEN_ONLY_ARCHS = ("dense", "moe", "ssm", "hybrid")


@dataclass
class Request:
    """One request's lifecycle record; ``run()`` returns these so callers
    can distinguish completion (``done=True``) from truncation by
    ``max_steps`` (``done=False`` with partial/empty ``out``)."""
    rid: int
    prompt: np.ndarray                 # (len,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0):
        if cfg.arch_type not in TOKEN_ONLY_ARCHS:
            raise ValueError(
                f"{cfg.name}: the engine drives token-only decoders "
                f"({'/'.join(TOKEN_ONLY_ARCHS)}), not {cfg.arch_type}")
        self.cfg, self.params = cfg, params
        self.model = get_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        # FIFO admission queue: deque so heavy-traffic admission stays O(1)
        # per pop (a list's pop(0) is O(n) in queued requests)
        self.queue: Deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.finished: Dict[int, Request] = {}
        self.stats = {"decode_steps": 0, "decode_traces": 0,
                      "prefills": 0, "prefill_traces": 0}
        self._rng = jax.random.key(seed)
        # the slot table: one batched cache, per-slot position vector
        self._cache = self.model.init_cache(cfg, slots, max_len)
        self._cache["pos"] = jnp.zeros((slots,), jnp.int32)
        self._pos = np.zeros(slots, np.int64)    # host mirror: tokens in ctx
        self._last = np.zeros(slots, np.int64)   # host mirror: last token
        # bucketing: attention masks make right-padding exact for dense;
        # MoE capacity routing and the SSM recurrence are perturbed by pad
        # tokens, so those archs prefill at exact length (retrace per len).
        self._bucketed = cfg.arch_type == "dense"
        self._window = (self._cache["kv"]["k"].shape[2]
                        if "kv" in self._cache else max_len)
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_fn, donate_argnums=(1,))

    # ------------------------------------------------------- jitted steps
    def _decode_fn(self, params, cache, tokens, pos, active, rng):
        """ONE device program advancing every slot: batched decode +
        on-device sampling + active-slot masking."""
        self.stats["decode_traces"] += 1     # Python side effect: trace-time only
        logits, cache = self.model.decode_step(params, cache, tokens, pos,
                                               self.cfg)
        tok = sample_tokens(logits[:, -1], rng=rng,
                            temperature=self.temperature)
        tok = jnp.where(active, tok, 0)
        cache["pos"] = jnp.where(active, pos + 1, pos)
        return tok, cache

    def _prefill_fn(self, params, cache, tokens, last_pos, slot, rng):
        """Prefill one (bucket-padded) prompt, sample its first token, and
        scatter the fresh per-request cache into slot-table row ``slot``.
        Retraces once per distinct padded length (= per bucket)."""
        self.stats["prefill_traces"] += 1
        c1 = self.model.init_cache(self.cfg, 1, self.max_len)
        if self._bucketed:
            logits, c1 = self.model.prefill(params, {"tokens": tokens},
                                            self.cfg, c1, last_pos=last_pos)
        else:
            logits, c1 = self.model.prefill(params, {"tokens": tokens},
                                            self.cfg, c1)
        tok = sample_tokens(logits[0, -1], rng=rng,
                            temperature=self.temperature)
        out = {}
        for key, big in cache.items():
            if key == "pos":
                out[key] = big.at[slot].set(last_pos + 1)
            else:
                out[key] = jax.tree.map(
                    lambda b, o: b.at[:, slot].set(o[:, 0]), big, c1[key])
        return tok, out

    def _next_rng(self):
        if self.temperature <= 0.0:
            return None
        self._rng, key = jax.random.split(self._rng)
        return key

    # --------------------------------------------------------- scheduling
    def submit(self, rid: int, prompt: np.ndarray, max_new: int):
        """Queue a request. Rejects inputs the cache cannot hold instead of
        silently clamping writes into the last row."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {rid}: empty prompt")
        if prompt.size >= self.max_len:
            raise ValueError(
                f"request {rid}: prompt length {prompt.size} >= max_len "
                f"{self.max_len}; the longest servable prompt is "
                f"{self.max_len - 1} tokens")
        if max_new < 1:
            raise ValueError(f"request {rid}: max_new must be >= 1")
        self.queue.append(Request(rid, prompt, int(max_new)))

    def _free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if self.active[s] is None:
                return s
        return None

    def _admit(self):
        while self.queue:
            s = self._free_slot()
            if s is None:
                return
            req = self.queue.popleft()
            n = len(req.prompt)
            blen = prefill_bucket(n, cap=self._window) if self._bucketed \
                else n
            padded = np.zeros(blen, np.int32)
            padded[:n] = req.prompt
            tok, self._cache = self._prefill(
                self.params, self._cache, jnp.asarray(padded[None]),
                jnp.asarray(n - 1, jnp.int32), jnp.asarray(s, jnp.int32),
                self._next_rng())
            self.stats["prefills"] += 1
            tok = int(tok)
            req.out.append(tok)
            self._pos[s] = n
            self._last[s] = tok
            # honor max_new / EOS on the PREFILL-sampled token: a request
            # that is already complete never occupies a slot, so output
            # length is exactly min(max_new, tokens-until-EOS)
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if req.max_new <= 1 or hit_eos:
                req.done = True
                self.finished[req.rid] = req
            else:
                self.active[s] = req

    def _retire(self, s: int):
        req = self.active[s]
        req.done = True
        self.finished[req.rid] = req
        self.active[s] = None

    # -------------------------------------------------------------- serve
    def step(self):
        """Admit from the queue, then advance EVERY active slot with one
        batched device call (no call at all if the table is empty)."""
        self._admit()
        mask = np.array([r is not None for r in self.active])
        if not mask.any():
            return
        tok, self._cache = self._decode(
            self.params, self._cache,
            jnp.asarray(self._last[:, None], jnp.int32),
            jnp.asarray(self._pos, jnp.int32), jnp.asarray(mask),
            self._next_rng())
        self.stats["decode_steps"] += 1
        toks = np.asarray(tok)
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            t = int(toks[s])
            req.out.append(t)
            self._pos[s] += 1
            self._last[s] = t
            hit_eos = self.eos_id is not None and t == self.eos_id
            if len(req.out) >= req.max_new or hit_eos or \
                    self._pos[s] >= self.max_len:
                self._retire(s)

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Serve until the queue and slot table drain (or ``max_steps``).

        Returns every submitted request's record: completed ones with
        ``done=True``, still-active ones with their partial output and
        still-queued ones with ``out == []`` (both ``done=False``) when
        ``max_steps`` is exhausted — nothing vanishes."""
        steps = 0
        while (self.queue or any(r is not None for r in self.active)) \
                and steps < max_steps:
            self.step()
            steps += 1
        results = dict(self.finished)
        for req in list(self.active) + list(self.queue):
            if req is not None:
                results[req.rid] = req
        return results
