"""AsyncDriver: the online front of the serve engine.

Everything below ``ServeEngine.step`` is a batch machine: submit, then
``run()`` to drain. Real traffic is the opposite shape — requests arrive
at any time, want their tokens AS they are produced, and a hung step must
page somebody instead of hanging the process. This module owns that gap
(sglang's scheduler loop + watchdog are the exemplar):

  * the driver runs the engine's step loop on a BACKGROUND thread,
    sleeping on a condition variable while idle (an idle server burns no
    CPU) and stepping whenever any request is queued or mid-decode;
  * ``submit()`` is thread-safe, can be called at any time, and returns a
    :class:`TokenStream` — iterate it to receive the request's tokens as
    each engine step produces them; ``result()`` blocks for the full
    record. Greedy streamed output is BIT-IDENTICAL to what a batch
    ``run()`` over the same submissions returns (test-pinned, dense +
    tp-sharded + dp-routed);
  * per-request TTFT (submit -> first token) and TPOT (inter-token gap)
    land in a :class:`~repro.serve.metrics.ServeMetrics` alongside
    per-step latency/occupancy — the numbers ``GET /metrics`` exposes
    and the DP router's tokens/s routing signal feeds from;
  * a WATCHDOG thread checks step wall time against
    ``watchdog_timeout``: an over-deadline step gets a diagnostic dump
    (queue depth, per-slot request/position table, allocator state —
    captured pre-step, so the dump never touches the engine mid-step)
    logged at ERROR, and when control returns to the loop every active
    slot is cancelled-and-requeued through the engine's EXISTING
    preemption path — partial outputs intact, greedy parity preserved by
    resume-by-re-prefill — instead of the stall wedging the slot table.

Locking: ONE lock serializes every engine touch (steps, submits, stats
reads). The watchdog never takes it — it reads the pre-step snapshot and
monotonic timestamps only, so a stalled step cannot stall its own
detection. Cancellation is cooperative: ``abort_step`` is set by the
watchdog; a single XLA call cannot observe it mid-flight (device calls
are uninterruptible), but the mixed-step engine polls it at every CHUNK
boundary (the driver wires ``engine.abort_event`` to this event at
construction) and instrumented ``step_fn``s (tests inject stalls this
way) return early — recovery then lands at sub-step latency instead of
waiting out the full step.

The driver serves a single :class:`~repro.serve.engine.ServeEngine` or a
:class:`~repro.serve.parallel.ReplicaRouter` identically (``step`` /
``busy`` / ``submit`` are the shared surface). Construction normally
goes through ``repro.api.Session.serve_async(...)`` or the HTTP layer in
serve/server.py.
"""
from __future__ import annotations

import logging
import queue as _queue
import threading
import time
from typing import Dict, List, Optional

log = logging.getLogger("repro.serve")

#: sentinel closing a TokenStream's queue
_DONE = object()


class TokenStream:
    """One request's live token feed.

    Iterating yields ints as the driver's step loop produces them and
    ends when the request completes; ``result()`` blocks until
    completion and returns the engine's full Request record (``out`` is
    the whole output, ``done`` distinguishes completion from a driver
    shutdown truncation). ``first_token_s`` is this request's TTFT once
    the first token exists (None before).
    """

    def __init__(self, rid: int):
        self.rid = rid
        self._q: _queue.Queue = _queue.Queue()
        self._done = threading.Event()
        self._record = None
        self.emitted = 0               # tokens pushed so far (driver-owned)
        self.first_token_s: Optional[float] = None

    # ------------------------------------------------------- driver side
    def _push(self, token: int):
        self.emitted += 1
        self._q.put(int(token))

    def _finish(self, record):
        self._record = record
        self._done.set()
        self._q.put(_DONE)

    # ------------------------------------------------------- caller side
    def __iter__(self):
        while True:
            item = self._q.get()
            if item is _DONE:
                return
            yield item

    def tokens(self) -> List[int]:
        """Drain the stream to completion and return every token."""
        return list(self)

    def result(self, timeout: Optional[float] = None):
        """Block until the request completes; returns the Request record
        (its ``out`` holds the full output). Raises TimeoutError when
        ``timeout`` elapses first."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.rid} still running after "
                               f"{timeout}s")
        return self._record

    @property
    def done(self) -> bool:
        return self._done.is_set()


class AsyncDriver:
    """Background step loop + per-request streaming + watchdog.

    Parameters
    ----------
    engine : ServeEngine | ReplicaRouter
        The machine to drive. The driver owns its step loop — do not call
        ``engine.step``/``run`` concurrently.
    watchdog_timeout : float | None
        Seconds a single step may take before the watchdog fires
        (diagnostic dump + cancel-and-requeue of every active slot once
        the step yields). None disables the watchdog thread.
    metrics : ServeMetrics | None
        Recording destination; a fresh one is built when omitted.
    start : bool
        Start the loop immediately. ``start=False`` lets a caller submit
        a whole batch first and then :meth:`start` — stepping then admits
        exactly like batch ``run()``, which the parity tests and the
        throughput bench use for determinism.
    step_fn : callable(driver) | None
        Override for one engine step (None -> ``engine.step()``). The
        instrumentation hook: tests inject stalls, a chunked step could
        poll ``driver.abort_step`` between chunks.
    """

    def __init__(self, engine, *, watchdog_timeout: Optional[float] = None,
                 metrics=None, start: bool = True, step_fn=None,
                 idle_wait_s: float = 0.2):
        from repro.serve.metrics import ServeMetrics

        self.engine = engine
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.watchdog_timeout = watchdog_timeout
        self._step_fn = step_fn
        self._idle_wait_s = idle_wait_s
        self._lock = threading.RLock()
        self._wake = threading.Condition(self._lock)
        self._streams: Dict[int, TokenStream] = {}
        self._requests: Dict[int, object] = {}    # rid -> Request record
        self._submit_t: Dict[int, float] = {}
        self._last_tok_t: Dict[int, float] = {}
        self._next_rid = 0
        self._stop_evt = threading.Event()
        self._started = False
        # ---- watchdog channel (never lock-guarded: the watchdog must
        # stay responsive while a stalled step holds the lock)
        self.abort_step = threading.Event()
        self._stall_fired = threading.Event()
        self._step_t0: Optional[float] = None
        self._last_step_done: Optional[float] = None
        self._snapshot: Dict = {}
        self._threads: List[threading.Thread] = []
        # chunk-boundary cancellation: a mixed-step engine polls this
        # event at the top of each step and skips launching its program
        # while set, so watchdog recovery lands at sub-step latency
        for e in self._engines():
            if hasattr(e, "abort_event"):
                e.abort_event = self.abort_step
        # previous engine-counter readings for per-step chunk telemetry
        self._prev_pf_tokens = 0
        self._prev_decode_tokens = 0
        # ... and for speculative-decode telemetry (independent set so
        # the two observers never couple through a shared counter)
        self._prev_spec = {"spec_drafted": 0, "spec_accepted": 0,
                           "decode_tokens": 0, "prefills": 0,
                           "decode_slot_steps": 0}
        if start:
            self.start()

    # ----------------------------------------------------------- control
    def start(self):
        """Launch the loop (and watchdog) threads; idempotent."""
        with self._lock:
            if self._started:
                return self
            self._started = True
        t = threading.Thread(target=self._loop, name="serve-driver",
                             daemon=True)
        t.start()
        self._threads = [t]
        if self.watchdog_timeout is not None:
            w = threading.Thread(target=self._watchdog_loop,
                                 name="serve-watchdog", daemon=True)
            w.start()
            self._threads.append(w)
        return self

    def stop(self, wait: bool = True, drain: bool = True,
             timeout: float = 30.0):
        """Shut the loop down. ``drain=True`` (default) keeps stepping
        until in-flight requests finish first; ``drain=False`` stops at
        the next step boundary and closes open streams with their
        partial records (``done=False``)."""
        if drain and self._started:
            self.join(timeout=timeout)
        self._stop_evt.set()
        with self._wake:
            self._wake.notify_all()
        if wait:
            for t in self._threads:
                t.join(timeout=timeout)
        with self._lock:
            for rid, stream in list(self._streams.items()):
                stream._finish(self._requests.get(rid))
            self._streams.clear()

    def join(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted request has completed (True) or
        ``timeout`` elapsed (False). The loop keeps running."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._lock:
                if not self._streams:
                    return True
                stream = next(iter(self._streams.values()))
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            if left == 0.0:
                return False
            try:
                stream.result(left)
            except TimeoutError:
                return False

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop(drain=not any(exc))

    # ------------------------------------------------------------ submit
    def submit(self, prompt, max_new: int = 16, *, rid: Optional[int] = None,
               frames=None, images=None, priority: int = 0,
               deadline_s: Optional[float] = None) -> TokenStream:
        """Thread-safe submission; returns the request's TokenStream.
        Validation failures (bad prompt/pool bounds) raise the engine's
        ValueError synchronously — nothing is enqueued. ``deadline_s``
        declares an SLO (see ServeEngine.submit): an expired-while-queued
        request's stream closes with ``done=False, expired=True``."""
        if self._stop_evt.is_set():
            raise RuntimeError("driver is stopped")
        t_submit = time.monotonic()
        with self._wake:
            if rid is None:
                rid = self._next_rid
            elif rid in self._streams:
                raise ValueError(f"request {rid} already in flight")
            self._next_rid = max(self._next_rid, rid + 1)
            req = self._engine_submit(rid, prompt, max_new, frames=frames,
                                      images=images, priority=priority,
                                      deadline_s=deadline_s)
            stream = TokenStream(rid)
            self._streams[rid] = stream
            self._requests[rid] = req
            self._submit_t[rid] = t_submit
            self.metrics.submitted.inc()
            self._wake.notify_all()
        return stream

    def _engine_submit(self, rid, prompt, max_new, *, frames, priority,
                       images=None, deadline_s=None):
        """Submit to either backend and return the Request record."""
        ret = self.engine.submit(rid, prompt, max_new, frames=frames,
                                 images=images, priority=priority,
                                 deadline_s=deadline_s)
        if isinstance(ret, int):       # ReplicaRouter returns the replica
            return self.engine.engines[ret].queue[-1]
        return ret

    # ----------------------------------------------------------- metrics
    def _engines(self) -> List:
        return list(getattr(self.engine, "engines", [self.engine]))

    def stats(self) -> Dict:
        """The backend's stats dict (router: aggregated), lock-guarded."""
        with self._lock:
            return dict(self.engine.stats)

    def render_metrics(self) -> str:
        """Prometheus text: driver latency metrics + engine telemetry."""
        return self.metrics.render(extra=self.stats())

    # ---------------------------------------- observability (lock-free)
    # None of these take the driver lock: a load balancer probing
    # /healthz or an operator pulling /debug/flight must get an answer
    # even while a stalled step holds the lock. Reads are monotonic
    # timestamps, deque lengths, and tracer state (its own small lock).

    def health(self) -> Dict:
        """Liveness + progress signals for ``GET /healthz``: a wedged-
        but-alive engine shows a growing ``last_step_age_s`` while
        ``queue_depth`` piles up."""
        now = time.monotonic()
        done = self._last_step_done
        t0 = self._step_t0
        return {
            "ok": True,
            "queue_depth": sum(len(e.queue) for e in self._engines()),
            "step_count": sum(e.stats["step_count"]
                              for e in self._engines()),
            "last_step_age_s": None if done is None else now - done,
            "step_in_flight_s": None if t0 is None else now - t0,
            "watchdog_fired": self._stall_fired.is_set(),
        }

    def flight(self, last: Optional[int] = None) -> Dict:
        """Flight-recorder snapshot: per-replica step-record rings and
        request spans plus the watchdog's pre-step snapshot."""
        now = time.monotonic()
        done = self._last_step_done
        return {
            "last_step_age_s": None if done is None else now - done,
            "snapshot": dict(self._snapshot),
            "replicas": [e.tracer.flight(last) for e in self._engines()
                         if getattr(e, "tracer", None) is not None],
        }

    def trace(self) -> Dict:
        """Merged Chrome ``trace_event`` JSON object across replicas."""
        from repro.serve.tracing import chrome_trace
        return chrome_trace([e.tracer for e in self._engines()
                             if getattr(e, "tracer", None) is not None])

    def export_trace(self, path: str) -> Dict:
        """Write the merged Chrome/Perfetto trace JSON to ``path``."""
        from repro.serve.tracing import export_chrome_trace
        return export_chrome_trace(
            path, [e.tracer for e in self._engines()
                   if getattr(e, "tracer", None) is not None])

    # ------------------------------------------------------------- loop
    def _busy(self) -> bool:
        engines = self._engines()
        return any(e.busy() for e in engines)

    def _take_snapshot(self):
        """Pre-step state for the watchdog's diagnostic dump — captured
        under the lock so the dump itself never touches the engine."""
        snap = {"queue_depth": 0, "active": [], "pools": [],
                # the id the in-flight step WILL get (engines stamp
                # begin_step with the pre-increment step_count), so the
                # stall report can name the stalled step
                "step_ids": [e.stats["step_count"]
                             for e in self._engines()]}
        for i, e in enumerate(self._engines()):
            snap["queue_depth"] += len(e.queue)
            for s, req in enumerate(e.active):
                if req is not None:
                    snap["active"].append(
                        {"replica": i, "slot": s, "rid": req.rid,
                         "pos": int(e._pos[s]), "out": len(req.out)})
            if e.paged:
                snap["pools"].append(
                    {"replica": i, "free_pages": e._alloc.free_pages,
                     "pages_in_use": e._alloc.pages_in_use})
        self._snapshot = snap

    def _loop(self):
        while not self._stop_evt.is_set():
            with self._wake:
                while not self._busy() and not self._stop_evt.is_set():
                    self._wake.wait(self._idle_wait_s)
                if self._stop_evt.is_set():
                    return
                self._step_once()

    def _step_once(self):
        """One engine step under the lock: snapshot, step (watchdog-
        timed), recover if the watchdog fired, then stream fresh tokens
        and record latencies."""
        self._take_snapshot()
        occupancy = len(self._snapshot["active"])
        self.metrics.occupancy.observe(occupancy)
        t0 = time.monotonic()
        self._step_t0 = t0
        try:
            if self._step_fn is not None:
                self._step_fn(self)
            else:
                self.engine.step()
        finally:
            self._step_t0 = None
        now = time.monotonic()
        self._last_step_done = now
        self.metrics.step_latency.observe(now - t0)
        if self._stall_fired.is_set():
            self._recover()
        self._observe_chunking()
        self._observe_spec()
        self._drain_phases()
        self._drain_tokens(now)
        self.metrics.queue_depth.set(
            sum(len(e.queue) for e in self._engines()))
        self.metrics.active_slots.set(
            sum(sum(r is not None for r in e.active)
                for e in self._engines()))

    def _observe_chunking(self):
        """Per-step mixed-batch telemetry: how many prefill-chunk tokens
        the step processed and what fraction of its work was prefill —
        counter DELTAS against the previous reading, clamped at zero so
        an ``engine.reset_stats()`` mid-flight resynchronizes instead of
        feeding negative samples."""
        if not any(getattr(e, "mixed", False) for e in self._engines()):
            return
        st = self.engine.stats
        pf, dec = st.get("prefill_chunk_tokens", 0), st["decode_tokens"]
        dpf = max(0, pf - self._prev_pf_tokens)
        ddec = max(0, dec - self._prev_decode_tokens)
        self._prev_pf_tokens, self._prev_decode_tokens = pf, dec
        if dpf + ddec > 0:
            self.metrics.prefill_chunk.observe(dpf)
            self.metrics.prefill_frac.observe(dpf / (dpf + ddec))

    def _observe_spec(self):
        """Speculative-decode telemetry (same delta-vs-previous pattern
        as :meth:`_observe_chunking`, its own counter set): export the
        drafted/accepted totals, the cumulative accept-rate gauge, and a
        tokens-per-decode-slot-step sample — decode tokens MINUS
        prefill-sampled first tokens over the step's (step, decoding
        slot) pair count, exactly 1.0 without speculation regardless of
        occupancy, so the >1.0 signal isolates what speculation bought."""
        if not any(getattr(e, "spec", None) is not None
                   for e in self._engines()):
            return
        st = self.engine.stats
        cur = {k: st.get(k, 0) for k in self._prev_spec}
        d = {k: max(0, cur[k] - self._prev_spec[k]) for k in cur}
        self._prev_spec = cur
        if d["spec_drafted"]:
            self.metrics.spec_drafted.inc(d["spec_drafted"])
        if d["spec_accepted"]:
            self.metrics.spec_accepted.inc(d["spec_accepted"])
        if cur["spec_drafted"] > 0:
            self.metrics.spec_accept_rate.set(
                cur["spec_accepted"] / cur["spec_drafted"])
        if d["decode_slot_steps"] >= 1:
            self.metrics.spec_tokens_per_step.observe(
                (d["decode_tokens"] - d["prefills"])
                / d["decode_slot_steps"])

    def _drain_phases(self):
        """Feed every engine's pending per-step phase timings into the
        ``serve_step_phase_seconds{phase=...}`` histogram (the tracer's
        pending deque decouples engine stepping from metric export)."""
        for e in self._engines():
            t = getattr(e, "tracer", None)
            if t is None:
                continue
            for _sid, phases, _dur in t.drain_phases():
                for ph, sec in phases.items():
                    self.metrics.step_phase.observe(ph, sec)

    def _drain_tokens(self, now: float):
        """Push every token the last step appended to its stream and
        record TTFT/TPOT; close out completed (or deadline-expired)
        requests."""
        for rid, stream in list(self._streams.items()):
            req = self._requests[rid]
            fresh = len(req.out) - stream.emitted
            if fresh > 0:
                # a step may append several tokens per request (catch-up
                # after deferred start, speculative accepts); spreading
                # the interval evenly across them keeps TPOT truthful —
                # the wall time really was shared by the whole group
                gap = now - self._last_tok_t.get(
                    rid, self._submit_t[rid])
                for _ in range(fresh):
                    if stream.emitted == 0:
                        # the engine stamps the first token's host time
                        # at prefill completion, so TTFT is correct even
                        # for requests that finish AT admission (the
                        # stream drains them on the same loop pass)
                        ft = getattr(req, "first_tok_t", None)
                        stream.first_token_s = \
                            (ft if ft is not None else now) \
                            - self._submit_t[rid]
                        self.metrics.ttft.observe(stream.first_token_s)
                    else:
                        self.metrics.tpot.observe(gap / fresh)
                    stream._push(req.out[stream.emitted])
                self._last_tok_t[rid] = now
                self.metrics.tokens.inc(fresh)
            expired = getattr(req, "expired", False)
            if req.done or expired:
                if req.done:
                    self.metrics.completed.inc()
                    self.metrics.e2e.observe(now - self._submit_t[rid])
                else:
                    self.metrics.expired.inc()
                stream._finish(req)
                del self._streams[rid]
                self._requests.pop(rid, None)
                self._submit_t.pop(rid, None)
                self._last_tok_t.pop(rid, None)
                self._forget(rid)

    def _forget(self, rid: int):
        """Drop the engine's finished record (the stream owns it now) so
        a long-lived server's ``finished`` dict stays bounded."""
        for e in self._engines():
            e.finished.pop(rid, None)
        home = getattr(self.engine, "_home", None)
        if home is not None:
            home.pop(rid, None)

    # ---------------------------------------------------------- watchdog
    def _watchdog_loop(self):
        interval = max(self.watchdog_timeout / 4.0, 0.01)
        while not self._stop_evt.wait(interval):
            t0 = self._step_t0
            if t0 is None or self._stall_fired.is_set():
                continue
            overrun = time.monotonic() - t0
            if overrun > self.watchdog_timeout:
                self.metrics.watchdog_fired.inc()
                log.error(self._stall_report(overrun))
                self._stall_fired.set()
                self.abort_step.set()

    def _stall_report(self, overrun: float) -> str:
        """Flight-recorder dump for a fired watchdog: names the stalled
        step id(s), every active slot, pool occupancy, and the tail of
        the step-record ring. Lock-free by construction — the pre-step
        snapshot plus tracer reads (the tracer has its OWN lock; the
        stalled thread is inside a device call, not inside the tracer)."""
        snap = self._snapshot
        sid = "/".join(str(i) for i in snap.get("step_ids", [])) or "?"
        lines = [f"serve watchdog: step {sid} stalled {overrun:.2f}s "
                 f"(timeout {self.watchdog_timeout}s); "
                 f"queue_depth={snap.get('queue_depth', 0)}"]
        for row in snap.get("active", []):
            lines.append(
                "  slot r{replica}/s{slot}: rid={rid} pos={pos} "
                "out={out}".format(**row))
        for pool in snap.get("pools", []):
            lines.append(
                "  pool r{replica}: {pages_in_use} pages in use, "
                "{free_pages} free".format(**pool))
        for i, e in enumerate(self._engines()):
            t = getattr(e, "tracer", None)
            if t is None or not t.enabled:
                continue
            for rec in t.flight(last=3)["steps"]:
                ph = " ".join(f"{k}={v * 1e3:.2f}ms"
                              for k, v in rec["phases"].items())
                lines.append(
                    f"  flight r{i} step {rec['step_id']}: "
                    f"dur={rec['dur'] * 1e3:.2f}ms "
                    f"produced={rec['produced']} {ph}")
        lines.append("  recovery: cancel-and-requeue every active slot "
                     "via the preemption path once the step yields")
        return "\n".join(lines)

    def _recover(self):
        """Post-stall recovery (loop thread, lock held): requeue every
        active request through the engine's preemption path. Partial
        outputs ride along; re-admission re-prefills prompt+output, so
        greedy token streams resume bit-identically."""
        requeued = 0
        for e in self._engines():
            for s in range(e.slots):
                if e.active[s] is not None:
                    e.preempt(s)
                    requeued += 1
        if requeued:
            self.metrics.watchdog_requeued.inc(requeued)
        log.error("serve watchdog: requeued %d active request(s) after "
                  "stalled step", requeued)
        self._stall_fired.clear()
        self.abort_step.clear()
