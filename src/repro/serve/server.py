"""Stdlib-only HTTP front-end over the AsyncDriver.

Three endpoints, no dependencies beyond ``http.server``:

  * ``POST /generate`` — body ``{"prompt": [ids...], "max_new": N,
    "stream": bool, "priority": int, "deadline_s": float}``.
    Non-streaming returns one JSON object ``{"rid", "tokens", "done",
    "expired"}`` when the request completes; ``"stream": true``
    switches to chunked transfer encoding and writes one JSON line PER
    TOKEN as the engine produces it (``{"rid", "token", "index"}``),
    closing with ``{"rid", "done": true, "expired": false,
    "tokens": [...]}`` — TTFT is the wire gap before the first line.
    ``deadline_s`` is a relative SLO: a request still QUEUED when it
    elapses is dropped (``done=false, expired=true``, no tokens) instead
    of occupying a slot it can no longer use. Validation failures
    (empty prompt, pool bounds, bad JSON, non-numeric ``"timeout"``)
    are HTTP 400 with the engine's message. A non-streaming request
    waits at most ``"timeout"`` seconds (client-set), else the server's
    ``result_timeout`` / watchdog timeout / 300s cap, and answers 504 —
    a wedged request never pins a handler thread forever.
  * ``GET /metrics`` — Prometheus text exposition: the driver's
    TTFT/TPOT/step summaries plus every numeric ``engine.stats`` field
    as ``serve_engine_*`` gauges (serve/metrics.py documents the
    glossary).
  * ``GET /healthz`` — ``{"status": "ok", ...}`` liveness probe with
    queue/slot occupancy, ``last_step_age_s``/``step_in_flight_s``
    progress signals (a wedged-but-alive engine shows a growing age
    while the queue piles up), and the watchdog-fired count; served
    LOCK-FREE so it answers even while a stalled step holds the driver
    lock — a load balancer can drain a replica whose watchdog keeps
    firing.
  * ``GET /debug/flight`` — flight-recorder snapshot (recent step
    records with per-phase timings + live/finished request span trees,
    per replica), also lock-free.
  * ``GET /debug/trace`` — the merged Chrome/Perfetto ``trace_event``
    JSON export (replica lanes as processes, engine-step + slot lanes
    as threads); load it in ui.perfetto.dev or chrome://tracing.

``ServeHTTPServer`` binds a ``ThreadingHTTPServer`` (port 0 picks a free
port — tests use that), serves on a daemon thread, and ``close()`` shuts
it down; it closes over an existing :class:`AsyncDriver` so the engine,
driver, and HTTP layers stay independently testable. Construction
normally goes through ``repro.api.Session.serve_http(...)`` or
``launch/serve.py --serve --port N``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.serve.driver import AsyncDriver

#: request body / streamed line size guard (1 MiB)
MAX_BODY_BYTES = 1 << 20

#: non-streaming /generate wait cap when neither the client sent a
#: "timeout" nor the server was built with ``result_timeout`` and the
#: driver runs no watchdog — a handler thread must never block forever
#: on a wedged or never-admitted request (it 504s instead)
DEFAULT_RESULT_TIMEOUT_S = 300.0


def _make_handler(driver: AsyncDriver,
                  result_timeout: Optional[float] = None):
    """Handler class closed over ``driver`` (BaseHTTPRequestHandler is
    instantiated per connection by the server, so state rides on the
    class). ``result_timeout`` caps how long a non-streaming /generate
    waits for completion when the client sent no ``"timeout"``; None
    falls back to the driver's watchdog timeout, then
    :data:`DEFAULT_RESULT_TIMEOUT_S`."""

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        server_version = "repro-serve/1.0"

        # silence the default per-request stderr lines; the metrics
        # endpoint is the observability story
        def log_message(self, fmt, *args):
            pass

        # ------------------------------------------------------ helpers
        def _send_json(self, obj, code: int = 200):
            body = (json.dumps(obj) + "\n").encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _send_text(self, text: str, code: int = 200,
                       ctype: str = "text/plain; version=0.0.4"):
            body = text.encode()
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _chunk(self, data: bytes):
            self.wfile.write(f"{len(data):x}\r\n".encode())
            self.wfile.write(data)
            self.wfile.write(b"\r\n")

        # --------------------------------------------------------- GET
        def do_GET(self):
            if self.path == "/metrics":
                self._send_text(driver.render_metrics())
            elif self.path == "/healthz":
                # LOCK-FREE on purpose: driver.health() never takes the
                # driver lock, so a load balancer still gets an answer —
                # with a growing last_step_age_s exposing the wedge —
                # while a stalled step holds it
                h = driver.health()
                h["status"] = "ok"
                h["busy"] = driver._busy()
                h["active_slots"] = int(
                    driver.metrics.active_slots.value)
                h["watchdog_fired"] = int(
                    driver.metrics.watchdog_fired.value)
                self._send_json(h)
            elif self.path == "/debug/flight":
                self._send_json(driver.flight())
            elif self.path == "/debug/trace":
                self._send_json(driver.trace())
            else:
                self._send_json({"error": f"no route {self.path}"}, 404)

        # -------------------------------------------------------- POST
        def do_POST(self):
            if self.path != "/generate":
                self._send_json({"error": f"no route {self.path}"}, 404)
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                if length > MAX_BODY_BYTES:
                    raise ValueError(
                        f"body {length}B exceeds {MAX_BODY_BYTES}B")
                spec = json.loads(self.rfile.read(length) or b"{}")
                prompt = spec["prompt"]
                if not isinstance(prompt, list) or \
                        not all(isinstance(t, int) for t in prompt):
                    raise ValueError("prompt must be a list of token ids")
                deadline_s = spec.get("deadline_s")
                # validate BEFORE submit: a non-numeric "timeout" must
                # 400 like any other bad field, not escape as a 500
                timeout = spec.get("timeout")
                if timeout is not None:
                    timeout = float(timeout)
                stream = driver.submit(
                    prompt, int(spec.get("max_new", 16)),
                    priority=int(spec.get("priority", 0)),
                    deadline_s=(None if deadline_s is None
                                else float(deadline_s)))
            except (KeyError, ValueError, TypeError,
                    json.JSONDecodeError) as e:
                self._send_json({"error": str(e)}, 400)
                return
            if spec.get("stream"):
                self._stream_response(stream)
            else:
                if timeout is None:
                    # no client timeout: never block the handler thread
                    # forever on a wedged/never-admitted request — wait
                    # at most the server-level cap, then 504
                    timeout = result_timeout \
                        if result_timeout is not None \
                        else (driver.watchdog_timeout
                              or DEFAULT_RESULT_TIMEOUT_S)
                try:
                    rec = stream.result(timeout=timeout)
                except TimeoutError as e:
                    self._send_json({"error": str(e),
                                     "rid": stream.rid}, 504)
                    return
                self._send_json({
                    "rid": stream.rid,
                    "tokens": list(rec.out),
                    "done": bool(rec.done),
                    "expired": bool(getattr(rec, "expired", False))})

        def _stream_response(self, stream):
            """Chunked transfer: one JSON line per token, then the
            closing record. A client disconnect mid-stream just stops
            the writes — the request itself finishes in the engine."""
            self.send_response(200)
            self.send_header("Content-Type", "application/jsonlines")
            self.send_header("Transfer-Encoding", "chunked")
            self.end_headers()
            tokens = []
            try:
                for i, tok in enumerate(stream):
                    tokens.append(tok)
                    self._chunk((json.dumps(
                        {"rid": stream.rid, "token": tok, "index": i})
                        + "\n").encode())
                rec = stream.result(timeout=0.0)
                self._chunk((json.dumps(
                    {"rid": stream.rid, "done": bool(rec.done),
                     "expired": bool(getattr(rec, "expired", False)),
                     "tokens": list(rec.out)}) + "\n").encode())
                self.wfile.write(b"0\r\n\r\n")
            except (BrokenPipeError, ConnectionResetError):
                pass

    return Handler


class ServeHTTPServer:
    """One HTTP front-end bound to an AsyncDriver.

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    ``close()`` stops the HTTP listener and, when the server owns its
    driver (``own_driver=True``), drains and stops the driver too.
    Usable as a context manager.
    """

    def __init__(self, driver: AsyncDriver, *, host: str = "127.0.0.1",
                 port: int = 0, own_driver: bool = False,
                 result_timeout: Optional[float] = None):
        self.driver = driver
        self._own_driver = own_driver
        self._httpd = ThreadingHTTPServer(
            (host, port), _make_handler(driver,
                                        result_timeout=result_timeout))
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="serve-http", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self, drain: bool = True):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=10.0)
        if self._own_driver:
            self.driver.stop(drain=drain)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close(drain=not any(exc))


def serve_http(engine, *, host: str = "127.0.0.1", port: int = 0,
               watchdog_timeout: Optional[float] = None,
               metrics=None,
               result_timeout: Optional[float] = None) -> ServeHTTPServer:
    """Wrap ``engine`` (ServeEngine or ReplicaRouter) in an AsyncDriver
    and expose it over HTTP; the returned server owns the driver
    (``close()`` stops both). ``result_timeout`` caps non-streaming
    /generate waits when the client sends no ``"timeout"`` (default:
    the watchdog timeout, else 300s — a wedged request 504s instead of
    pinning its handler thread forever)."""
    driver = AsyncDriver(engine, watchdog_timeout=watchdog_timeout,
                         metrics=metrics)
    return ServeHTTPServer(driver, host=host, port=port, own_driver=True,
                           result_timeout=result_timeout)
