"""Plan-aware sharded serving: TP-sharded engines x DP replica routing.

The survey's parallel-serving decomposition (Nagrecha 2023) splits a
model server along the same two axes as training: INTRA-operator
parallelism shards one replica's operators across ``tp`` devices, and
DATA parallelism replicates whole engines ``dp`` times and load-balances
requests between them. The first half lives in the ServeEngine itself —
``ServeEngine(..., mesh=...)`` runs its one-trace prefill/decode
programs GSPMD-sharded (Megatron param layout, head-sharded paged KV
pool; see the engine docstring). This module supplies the second half
plus the glue that turns a planner :class:`~repro.core.planner.Plan`
into a serving topology:

  * :func:`replica_meshes` — carve ``dp * tp`` devices into ``dp``
    disjoint ("data", "model") = (1, tp) sub-meshes, one per replica
    (rows of a materialized plan's mesh, so `Session.from_plan(...)
    .serve()` serves on exactly the devices the plan reserved);
  * :class:`ReplicaRouter` — instantiates one engine per sub-mesh and
    routes ``submit()`` by LATENCY-AWARE least load: once every replica
    has a decoded-tokens/s EWMA (``engine.stats["tokens_per_s_ewma"]``,
    updated each step) the routing score is ``load / rate`` — the
    estimated backlog-drain time — so slow replicas get less traffic
    than raw queue depth would give them; queue-depth (queued + active
    requests, lowest replica index breaking ties) remains the
    COLD-START fallback until all replicas have decoded. PREFIX
    AFFINITY applies on top when the engines run a prefix cache: requests opening with the same page-aligned
    first block prefer the replica that already holds those shared
    pages, so a common system prompt stays ONE physical copy per
    replica instead of bouncing across all of them — unless that
    replica is more than a slot-table's worth of load behind, in which
    case least-load wins (affinity must not recreate head-of-line
    blocking across replicas). ``run()`` advances every busy replica
    round-robin until all drain; ``stats`` aggregates the counters and
    keeps the per-replica breakdown (each replica still traces decode
    exactly once — CI-asserted).

Construction normally goes through ``repro.api.Session.serve(plan=...)``
/ ``launch/serve.py --tp/--dp``; the router is independently usable with
hand-built device lists for tests and benchmarks.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.launch.mesh import make_mesh
from repro.serve.engine import Request, ServeEngine


def replica_meshes(dp: int, tp: int, devices: Optional[Sequence] = None
                   ) -> List:
    """``dp`` disjoint ("data", "model") = (1, tp) meshes over the first
    ``dp * tp`` devices (or the given sequence / a materialized plan
    mesh's ``.devices`` array, whose rows are the replica slices)."""
    import jax

    if dp < 1 or tp < 1:
        raise ValueError(f"dp and tp must be >= 1, got dp{dp} tp{tp}")
    if devices is None:
        devices = jax.devices()
    devs = list(np.asarray(devices).reshape(-1))
    if dp * tp > len(devs):
        raise ValueError(
            f"dp{dp} x tp{tp} = {dp * tp} devices needed but only "
            f"{len(devs)} available (force more host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return [make_mesh((1, tp), ("data", "model"),
                      devices=devs[r * tp:(r + 1) * tp])
            for r in range(dp)]


class ReplicaRouter:
    """``dp`` ServeEngine replicas behind one submit/run/stats facade.

    Every engine kwarg (slots, max_len, paged, page_size, prefix_cache,
    lazy, scheduler factory output, ...) applies to each replica;
    ``params`` are resharded onto every replica's sub-mesh (the Megatron
    TP layout within, full replication across). ``scheduler`` may not be
    a shared mutable policy OBJECT across replicas — pass a fresh one
    per replica via ``scheduler_factory`` if the policy keeps state (the
    shipped policies are stateless, so sharing them is fine).
    """

    def __init__(self, cfg, params, *, dp: int, tp: int = 1,
                 devices: Optional[Sequence] = None, strategy=None,
                 **engine_kw):
        self.dp, self.tp = int(dp), int(tp)
        self.meshes = replica_meshes(self.dp, self.tp, devices)
        self.engines: List[ServeEngine] = [
            ServeEngine(cfg, params, mesh=mesh, strategy=strategy,
                        **engine_kw)
            for mesh in self.meshes]
        self.cfg = cfg
        # stamp each engine's tracer with its replica index so a merged
        # Chrome export gets one process lane per replica (ids collide
        # otherwise: every engine numbers its steps/slots from zero)
        for r, e in enumerate(self.engines):
            e.tracer.replica = r
        self._home: Dict[int, int] = {}      # rid -> replica index
        self._affine: Dict[Tuple, int] = {}  # first-block key -> replica

    # ----------------------------------------------------------- routing
    def _load(self, r: int) -> int:
        e = self.engines[r]
        return len(e.queue) + sum(a is not None for a in e.active)

    def _rate(self, r: int) -> float:
        """Replica ``r``'s decoded-tokens/s EWMA (engine.stats, updated
        every step) — 0.0 until the replica has decoded anything."""
        return float(self.engines[r].stats["tokens_per_s_ewma"])

    def _affinity_key(self, prompt: np.ndarray) -> Optional[Tuple]:
        """Page-aligned first block of the prompt — the unit the prefix
        cache shares — as the routing key. None when the engines run no
        prefix cache or the prompt has no full block to share."""
        e = self.engines[0]
        if e._prefix is None or len(prompt) < e.page_size:
            return None
        return tuple(int(t) for t in prompt[:e.page_size])

    def route(self, prompt: np.ndarray) -> int:
        """Replica index for ``prompt``: LATENCY-AWARE least-load once
        every replica has a decoded-tokens/s EWMA — the score is
        ``load / rate``, the estimated time for the replica to chew
        through its current backlog, so a replica that decodes slower
        (longer contexts, colder cache, noisier host) gets
        proportionally less traffic than raw queue depth would give it.
        Until every replica has decoded something (cold start) the
        queue-depth proxy decides, exactly as before. The prefix-
        AFFINITY override is unchanged: the replica already holding the
        prompt's first shared block wins while its request-count load is
        within one slot-table of the minimum. Pure — ``submit`` records
        the routing decision."""
        loads = [self._load(r) for r in range(self.dp)]
        rates = [self._rate(r) for r in range(self.dp)]
        if all(rate > 0.0 for rate in rates):
            best = min(range(self.dp),
                       key=lambda r: (loads[r] / rates[r], loads[r], r))
        else:
            best = min(range(self.dp), key=lambda r: (loads[r], r))
        key = self._affinity_key(np.asarray(prompt).reshape(-1))
        if key is not None:
            aff = self._affine.get(key)
            if aff is not None and \
                    loads[aff] <= loads[best] + self.engines[aff].slots:
                return aff
        return best

    def submit(self, rid: int, prompt, max_new: int, *,
               frames=None, images=None, priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Route and enqueue one request; returns the replica index it
        landed on. Validation (prompt/pool bounds) is the target
        engine's — its ValueError propagates before any state changes."""
        if rid in self._home:
            raise ValueError(f"request {rid} was already submitted "
                             f"(to replica {self._home[rid]})")
        r = self.route(prompt)
        self.engines[r].submit(rid, prompt, max_new, frames=frames,
                               images=images, priority=priority,
                               deadline_s=deadline_s)
        self._home[rid] = r
        key = self._affinity_key(np.asarray(prompt, np.int32).reshape(-1))
        if key is not None and key not in self._affine:
            self._affine[key] = r
        return r

    # ----------------------------------------------------------- serving
    def step(self):
        """Advance every busy replica by one engine step (idle replicas
        cost nothing — their engines skip the device call)."""
        for e in self.engines:
            if e.busy():
                e.step()

    def run(self, max_steps: int = 10_000) -> Dict[int, Request]:
        """Round-robin step every replica until all drain (or
        ``max_steps`` engine steps each); returns the union of every
        replica's request records — completed, partial and queued."""
        steps = 0
        while any(e.busy() for e in self.engines) and steps < max_steps:
            self.step()
            steps += 1
        out: Dict[int, Request] = {}
        for e in self.engines:
            out.update(e.results())
        return out

    def release_prefix_cache(self) -> int:
        return sum(e.release_prefix_cache() for e in self.engines)

    # ------------------------------------------------------------- stats
    @property
    def stats(self) -> Dict:
        """Counter sums across replicas, plus ``replicas`` — the
        per-engine dicts (trace counters are per-replica properties;
        their sum only says "one trace EACH" when every entry is 1).
        The PR 6 telemetry fields aggregate without double counting
        because replicas are disjoint machines: ``step_count`` /
        ``decode_tokens`` / ``wall_time_s`` sum to fleet totals (wall
        time is cumulative engine-step seconds, not elapsed wall clock),
        and ``tokens_per_s_ewma`` — a rate — sums to the fleet's
        aggregate decode rate; per-replica rates stay readable under
        ``replicas``."""
        per = [dict(e.stats) for e in self.engines]
        agg: Dict = {}
        for k, v in per[0].items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                # identity fields (decode_backend) don't sum; replicas
                # are homogeneous, so replica 0's value speaks for all
                agg[k] = v
            else:
                agg[k] = sum(p[k] for p in per)
        agg["replicas"] = per
        return agg

    def reset_stats(self):
        """Steady-state measurement hook: resets every replica's
        counters (trace counters stay monotonic — see
        ServeEngine.reset_stats)."""
        for e in self.engines:
            e.reset_stats()

    def replica_of(self, rid: int) -> Optional[int]:
        return self._home.get(rid)

    def kv_bytes(self) -> int:
        """Global resident decode-state bytes across all replicas."""
        return sum(e.kv_bytes() for e in self.engines)

    def per_device_kv_bytes(self) -> int:
        """Resident decode-state bytes on one device (replicas are
        disjoint, so the max over engines is the per-device figure)."""
        return max(e.per_device_kv_bytes() for e in self.engines)

    # ----------------------------------------------------- observability
    @property
    def tracers(self) -> List:
        """Every replica's tracer (already replica-stamped)."""
        return [e.tracer for e in self.engines]

    def trace(self) -> Dict:
        """ONE merged Chrome ``trace_event`` object: replica ``r`` is
        process lane ``r``, so per-replica step/slot ids never collide."""
        from repro.serve.tracing import chrome_trace
        return chrome_trace(self.tracers)

    def export_trace(self, path: str) -> Dict:
        """Write the merged Chrome/Perfetto trace JSON to ``path``."""
        from repro.serve.tracing import export_chrome_trace
        return export_chrome_trace(path, self.tracers)

    def flight(self, last: Optional[int] = None) -> Dict:
        """Per-replica flight-recorder snapshots, one merged dict."""
        return {"replicas": [t.flight(last) for t in self.tracers]}
