"""Speculative multi-token decode: self-drafting over the mixed step.

Decode throughput is bounded by ONE memory-bound program per token — the
inference wall the survey's §5 case-studies keep hitting. Speculative
decoding restructures the schedule instead of the kernel: a cheap DRAFTER
guesses the next ``k`` tokens, the real model VERIFIES all of them in a
single dispatch, and the greedy-matching prefix is accepted — plus one
"bonus" token the verifier's own logits supply for free. Each step then
yields between 1 (all drafts rejected: exactly the non-speculative token)
and ``k + 1`` tokens for one program launch, and greedy output is
BIT-IDENTICAL to non-speculative decode by construction: every emitted
token is an argmax of the verifier's logits at its own position.

The PR 7 mixed token-slot step was built to host this: its (T, 1) batch
already carries per-row ``pos``/``slot`` tags, so drafted tokens are just
EXTRA ROWS with the same slot id at consecutive positions — no new
program, no new trace shape (the batch stays statically ``chunk_tokens``
wide). Rejection rollback is page-table bookkeeping: the engine truncates
the slot's reservation back to its accepted cursor
(``PageAllocator.rollback``) and the stale KV beyond it is invisible
(attention masks by ``pos``) and overwritten before it could ever be
gathered.

Two SELF-speculative drafters ship — neither needs a second model:

  * :class:`NgramDrafter` (``drafter="ngram"``, the default) — prompt
    lookup: match the longest recent n-gram of the slot's context
    (prompt + generated) against its OWN earlier tokens and propose the
    continuation of the most recent match. Free, and strong exactly
    where speculation pays: repetitive text (code, templated prose,
    retrieval-stuffed prompts). No match -> no draft rows -> plain
    one-token decode, so it can never be slower than k=0 by more than
    the host-side lookup.
  * :class:`DraftModelDrafter` (``drafter="model"``) — a small greedy
    dense model proposes the continuation. Runs its own (bucketed, so
    trace-bounded) forward over the context; accepted wherever its
    argmax agrees with the verifier's. Pass ``draft_cfg``/
    ``draft_params`` (e.g. a trained tiny config); omitted params are
    freshly initialized, which demonstrates the plumbing but drafts at
    chance level.

Correctness does not depend on the drafter: a bad draft costs budget
rows, never tokens. ``SpecConfig`` is accepted by ``ServeEngine(spec=)``
/ ``Session.serve(spec=)`` and requires the mixed step (paged layout)
and greedy sampling (``temperature == 0`` — acceptance compares argmax
tokens; stochastic speculative sampling is a different acceptance rule).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

DRAFTERS = ("ngram", "model")


@dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs for ``ServeEngine(spec=...)``.

    ``k`` drafted tokens are verified per slot per step (the engine
    packs ``k + 1`` rows — draft rows plus the slot's base decode row —
    so ``chunk_tokens`` must cover ``slots * (k + 1)``). ``ngram_min`` /
    ``ngram_max`` bound the n-gram match length of the prompt-lookup
    drafter (longest first). ``draft_cfg``/``draft_params``/
    ``draft_seed`` configure the small-model drafter; ``draft_cfg=None``
    with ``drafter="model"`` derives a 1-layer dense config over the
    verifier's vocab, and ``draft_params=None`` initializes it fresh
    from ``draft_seed``.
    """
    k: int = 4
    drafter: str = "ngram"
    ngram_min: int = 1
    ngram_max: int = 4
    draft_cfg: Optional[object] = None       # ModelConfig for "model"
    draft_params: Optional[object] = None    # param tree for "model"
    draft_seed: int = 0

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"spec.k must be >= 1, got {self.k}")
        if self.drafter not in DRAFTERS:
            raise ValueError(
                f"spec.drafter must be one of {'/'.join(DRAFTERS)}, "
                f"got {self.drafter!r}")
        if not 1 <= self.ngram_min <= self.ngram_max:
            raise ValueError(
                f"need 1 <= ngram_min <= ngram_max, got "
                f"{self.ngram_min}/{self.ngram_max}")


class NgramDrafter:
    """Prompt-lookup drafting: propose the continuation of the most
    recent earlier occurrence of the context's longest trailing n-gram.

    ``propose(ctx, k)`` takes the slot's full known token sequence
    (prompt + every generated token, the pending one included) and
    returns up to ``k`` drafted continuation tokens — possibly EMPTY
    (no n-gram of length >= ``ngram_min`` recurs), in which case the
    engine packs a plain one-row decode for the slot. Longest n-gram
    first (``ngram_max`` down to ``ngram_min``), most recent match
    wins: repetitive contexts draft their own loop body.
    """

    def __init__(self, *, ngram_min: int = 1, ngram_max: int = 4):
        if not 1 <= ngram_min <= ngram_max:
            raise ValueError(f"need 1 <= ngram_min <= ngram_max, got "
                             f"{ngram_min}/{ngram_max}")
        self.ngram_min = ngram_min
        self.ngram_max = ngram_max

    def propose(self, ctx: np.ndarray, k: int) -> np.ndarray:
        ctx = np.asarray(ctx).reshape(-1)
        n = len(ctx)
        for g in range(min(self.ngram_max, n - 1), self.ngram_min - 1, -1):
            tail = ctx[n - g:]
            # most recent earlier occurrence with at least one
            # continuation token to propose
            for i in range(n - g - 1, -1, -1):
                if np.array_equal(ctx[i:i + g], tail):
                    cont = ctx[i + g:i + g + k]
                    return np.asarray(cont, np.int64)
        return np.zeros((0,), np.int64)


class DraftModelDrafter:
    """Greedy small-model drafting: a separate (tiny, dense) model
    proposes the next ``k`` tokens by its own argmax.

    The draft forward runs over the context padded to a power-of-two
    bucket (``serve/step.prefill_bucket``), so the drafter retraces at
    most log2(max_len) shapes regardless of context length — the same
    bounded-trace discipline as the verifier. Causal attention makes
    tail padding invisible to every real position, so one buffer serves
    all ``k`` proposal steps at one trace: token ``i``'s draft is the
    argmax at position ``len(ctx) - 1 + i`` after writing the previous
    drafts into the buffer.
    """

    def __init__(self, cfg, params=None, *, max_len: int = 256, seed: int = 0):
        import jax

        from repro.models import get_model

        if cfg.arch_type != "dense":
            raise ValueError(
                f"{cfg.name}: the draft model must be a dense decoder "
                f"(row-independent greedy argmax), not {cfg.arch_type}")
        self.cfg = cfg
        self.max_len = int(max_len)
        self.model = get_model(cfg)
        self.params = params if params is not None \
            else self.model.init(jax.random.key(seed), cfg)
        self._fwd = jax.jit(
            lambda p, t: self.model.forward(p, {"tokens": t}, cfg)[0])

    def propose(self, ctx: np.ndarray, k: int) -> np.ndarray:
        from repro.serve.step import prefill_bucket

        ctx = np.asarray(ctx).reshape(-1)
        n = len(ctx)
        k = min(int(k), self.max_len - n)
        if k <= 0:
            return np.zeros((0,), np.int64)
        b = prefill_bucket(n + k, cap=self.max_len)
        buf = np.zeros((1, b), np.int32)
        buf[0, :n] = ctx
        out = []
        for i in range(k):
            logits = np.asarray(self._fwd(self.params, buf))
            t = int(np.argmax(logits[0, n - 1 + i]))
            out.append(t)
            if n + i < b:
                buf[0, n + i] = t
        return np.asarray(out, np.int64)


def make_drafter(spec: SpecConfig, cfg, *, max_len: int, seed: int = 0):
    """Build the drafter a :class:`SpecConfig` names. ``cfg`` is the
    VERIFIER's config — the "model" drafter derives its default tiny
    draft config from it (1 dense layer over the same vocab) when
    ``spec.draft_cfg`` is omitted."""
    if spec.drafter == "ngram":
        return NgramDrafter(ngram_min=spec.ngram_min,
                            ngram_max=spec.ngram_max)
    draft_cfg = spec.draft_cfg
    if draft_cfg is None:
        from repro.configs.base import ModelConfig
        draft_cfg = ModelConfig(
            name=f"{cfg.name}-draft", arch_type="dense", num_layers=1,
            d_model=64, num_heads=2, num_kv_heads=2, d_ff=128,
            vocab_size=cfg.vocab_size, dtype="float32")
    if draft_cfg.vocab_size < cfg.vocab_size:
        raise ValueError(
            f"draft model vocab {draft_cfg.vocab_size} < verifier vocab "
            f"{cfg.vocab_size}: the drafter could never propose every "
            "token")
    return DraftModelDrafter(draft_cfg, spec.draft_params,
                             max_len=max_len,
                             seed=seed + spec.draft_seed)
