"""Shared-prefix KV cache: a radix tree over page-aligned token blocks.

N requests that open with the same system prompt should not hold N
physical copies of its KV. This module maps PROMPT PREFIXES to pages of
the engine's paged pool (serve/paging.py): the tree is keyed block-wise —
one edge per ``page_size``-token block, keyed by the exact token tuple —
so a lookup walks the request's context one full block at a time and
returns the pool pages that already hold that prefix's KV. The engine
adopts them (refcount += 1) instead of allocating and recomputing writes.

Why block sharing is EXACT: with causal attention, the K/V at position p
is a function of tokens 0..p only — a donor request whose context starts
with the same blocks computed bit-identical KV for those positions,
whatever its suffix was (padding is right-aligned and masked). Two archs
need a coarser key, supplied by the engine as a ``salt`` namespace that
prefixes every path through the tree:

  * enc-dec decoders cross-attend to the encoder output, so decoder KV
    depends on the FRAMES too — the engine salts with a digest of the
    request's frame embeddings (same audio + same prompt prefix shares);
  * MoE capacity routing makes token p's expert assignment depend on the
    whole sequence (capacity ~ total tokens), so block KV is only
    portable between IDENTICAL contexts — the engine salts with a digest
    of the full context, turning sharing into exact-duplicate dedup.

Lifetime: each registered block holds ONE cache reference on its page
(``allocator.ref``), so pages survive their last owner's retirement and a
later request with the same prefix still hits — a preempted victim's
re-prefill is cheap because its prefix pages are usually still resident.
Under pool pressure the engine evicts least-recently-matched leaves
(``evict_one``): only pages whose refcount is exactly the cache's own
reference are reclaimable, so sharing never steals a live request's
pages.

Partial-tail matching (``want_tail``) is the copy-on-write hook: when the
context ends mid-block, a registered block whose first tokens equal the
context's tail can back that last partial page too. The adopting request
will WRITE into that page at its first decode step, so the engine must
``allocator.cow`` + device-copy it first — see ServeEngine._grow_and_cow.

Sharded serving: the cache deals only in page ids and token tuples, so
it is blind to TP sharding (a head-sharded pool page is still one page
id) — but it is strictly PER-REPLICA: under data-parallel serving each
engine replica owns its own pool and its own tree, and sharing across
replicas happens by ROUTING, not by reference. The ReplicaRouter
(serve/parallel.py) keys affinity on the same unit this tree does — the
page-aligned first token block — steering same-prefix requests to the
replica whose tree already holds those pages.
"""
from __future__ import annotations

from itertools import count
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


class _Node:
    __slots__ = ("key", "page", "parent", "children", "stamp")

    def __init__(self, key, page: int, parent: Optional["_Node"],
                 stamp: int):
        self.key = key                  # block token tuple (None for roots)
        self.page = page                # pool page holding this block's KV
        self.parent = parent
        self.children: Dict[tuple, _Node] = {}
        self.stamp = stamp              # LRU: last match/insert touch


class PrefixCache:
    """Radix tree of page-aligned token blocks -> refcounted pool pages."""

    def __init__(self, allocator, page_size: int):
        self.alloc = allocator
        self.page_size = page_size
        self._roots: Dict[Hashable, _Node] = {}
        self._clock = count()
        self.hit_blocks = 0            # blocks served from the cache
        self.miss_blocks = 0           # full blocks computed fresh
        self.tail_hits = 0             # partial-tail (CoW-bound) hits

    # -------------------------------------------------------------- sizing
    def __len__(self) -> int:
        """Registered blocks (= cache references held on the pool)."""
        return sum(self._count(r) for r in self._roots.values())

    def _count(self, node: _Node) -> int:
        return sum(1 + self._count(c) for c in node.children.values())

    def _blocks(self, tokens: Sequence[int]) -> List[tuple]:
        ps = self.page_size
        return [tuple(int(t) for t in tokens[i:i + ps])
                for i in range(0, len(tokens) - len(tokens) % ps, ps)]

    # ------------------------------------------------------------ matching
    def match(self, tokens: Sequence[int], *, salt: Hashable = None,
              want_tail: bool = False
              ) -> Tuple[List[int], Optional[int], int]:
        """Longest-prefix lookup for ``tokens`` under the ``salt``
        namespace. Returns ``(pages, tail_page, matched_tokens)``:
        ``pages`` are the pool pages backing the matched FULL blocks (in
        block order), ``tail_page`` (only with ``want_tail``) additionally
        backs the context's final partial block when some registered
        block STARTS with those tokens — adopting it obliges the caller
        to copy-on-write before writing into it. Matched nodes are
        LRU-touched; the hit/miss counters are the CALLER's to bump (on
        successful adoption — a backpressured admission re-matches every
        step and must not inflate them)."""
        node = self._roots.get(salt)
        pages: List[int] = []
        if node is None:
            return pages, None, 0
        stamp = next(self._clock)
        blocks = self._blocks(tokens)
        for key in blocks:
            child = node.children.get(key)
            if child is None:
                break
            child.stamp = stamp
            pages.append(child.page)
            node = child
        tail_page = None
        tail = tuple(int(t) for t in tokens[len(blocks) * self.page_size:])
        if want_tail and tail and len(pages) == len(blocks):
            for key, child in node.children.items():
                if key[:len(tail)] == tail:
                    child.stamp = stamp
                    tail_page = child.page
                    break
        return pages, tail_page, len(pages) * self.page_size

    # ----------------------------------------------------------- insertion
    def insert(self, tokens: Sequence[int], pages: Sequence[int], *,
               salt: Hashable = None) -> int:
        """Register the full blocks of ``tokens`` along one path, taking a
        cache reference on each newly registered page (``pages`` is the
        owner's block-ordered page list, shared head included). Blocks
        already registered keep their existing page — concurrent
        duplicates never fork the tree. Returns newly registered block
        count."""
        node = self._roots.get(salt)
        if node is None:
            node = self._roots[salt] = _Node(None, -1, None,
                                             next(self._clock))
        stamp = next(self._clock)
        added = 0
        for i, key in enumerate(self._blocks(tokens)):
            child = node.children.get(key)
            if child is None:
                self.alloc.ref(pages[i])
                child = _Node(key, pages[i], node, stamp)
                node.children[key] = child
                added += 1
            child.stamp = stamp
            node = child
        return added

    # ------------------------------------------------------------ eviction
    def _leaves(self) -> List[_Node]:
        out, stack = [], list(self._roots.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif n.parent is not None:         # skip empty roots
                out.append(n)
        return out

    def evictable_pages(self, keep: frozenset = frozenset()) -> int:
        """Blocks only the cache references (and outside ``keep``) —
        exactly what a full eviction sweep could free. Exact, not an upper
        bound: adoption always covers a root path (full blocks, then the
        tail), so an unreferenced node never has a referenced descendant
        blocking its turn as a leaf."""
        n = 0
        stack = list(self._roots.values())
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.parent is not None and node.page not in keep \
                    and self.alloc.refcount(node.page) == 1:
                n += 1
        return n

    def evict_one(self, keep: frozenset = frozenset()) -> bool:
        """Drop the least-recently-matched UNREFERENCED leaf (a page whose
        only reference is the cache's own — evicting never steals a page
        some live request still reads) and release its page. ``keep``
        protects pages mid-adoption. Returns False when nothing is
        evictable."""
        best = None
        for leaf in self._leaves():
            if leaf.page in keep or self.alloc.refcount(leaf.page) != 1:
                continue
            if best is None or leaf.stamp < best.stamp:
                best = leaf
        if best is None:
            return False
        del best.parent.children[best.key]
        self.alloc.deref(best.page)
        return True

    def flush(self) -> int:
        """Evict every evictable block (refcount-1 pages only); blocks a
        live request still shares stay registered. Returns evicted
        count."""
        n = 0
        while self.evict_one():
            n += 1
        return n
