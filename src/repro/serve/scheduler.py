"""Admission/preemption policy for the serve engine.

With LAZY page growth the engine reserves only the pages covering a
request's prompt plus its first decode write at admission and grows the
reservation on page-boundary crossings; the pool can therefore run dry MID-DECODE, which the worst-case
up-front reservation made impossible. Recovering is a policy decision,
factored out of the engine mechanics:

  * admission stays FIFO head-of-line (``next_index``): when the head
    cannot get pages the engine waits for retirements/evictions rather
    than admitting around it, so no request starves behind lucky late
    arrivals;
  * when ``extend``/``cow`` fails mid-decode, the engine first evicts
    unreferenced prefix-cache pages, then asks ``pick_victim`` for an
    active slot to PREEMPT: least-progress-first (fewest generated
    tokens — the cheapest re-prefill, and the newest admissions yield to
    requests that are nearly done), slot index as the deterministic
    tie-break;
  * a victim's pages are released (shared prefix pages merely drop one
    reference and usually stay resident in the prefix cache), and the
    request is requeued at the FRONT of the FIFO (``requeue``) with its
    partial output intact: re-prefill over prompt+output resumes decoding
    exactly where it stopped (greedy decode is bit-identical to the
    uninterrupted run), and a prefix hit on the still-resident pages makes
    that re-prefill cheap.

Liveness: every reclaim round either evicts a cache page or preempts a
slot, both finite; once every other slot is preempted and the cache is
flushed, the survivor's worst-case context fits the pool by the submit()
bound, so its extend succeeds — a pool sized below aggregate demand
serializes the workload instead of deadlocking (tested in
tests/test_serve_prefix.py::test_preemption_liveness_*).

Two policies ship:

  * ``FifoLeastProgress`` (default) — FIFO admission, fewest-generated-
    tokens victim;
  * ``Priority`` — ``submit(..., priority=N)`` requests with a HIGHER
    priority admit first (FIFO within a priority class, so equal-priority
    traffic cannot starve each other), and under pool pressure the
    LOWEST-priority active slot is preempted first (least progress, then
    slot index, as tie-breaks) — background traffic yields its pages to
    latency-sensitive requests. The head-of-line contract moves with the
    policy: when the top-priority request cannot be placed, nothing is.

Victim candidates are ``(slot, progress, priority)`` triples; policies
that ignore priority just read the first two fields.

Under SPECULATIVE decode (``ServeEngine(spec=...)``) every decoding
slot's drafted rows count against the mixed step's ``chunk_tokens``
budget ahead of any prefill chunk — the engine reserves ``1 + k_s`` rows
per slot (base decode row plus its drafts) before ``prefill_key``
ordering shares out what remains, so speculation can narrow prefill
chunks but never displace a decode row (the same decode-first contract
``serve/step.pack_token_budget`` enforces, now with per-slot row
counts).
"""
from __future__ import annotations

from typing import Deque, Dict, List, Optional, Sequence, Tuple


class FifoLeastProgress:
    """FIFO admission + least-progress preemption (the default policy).

    Requests carrying a DEADLINE (``submit(..., deadline_s=)``, an
    absolute monotonic time on ``Request.deadline``) jump the FIFO:
    admission is earliest-deadline-first with submission order breaking
    ties, and deadline-free requests sort as infinitely late — with no
    deadlines anywhere this is exactly the old FIFO. ``prefill_key``
    orders the mixed step's prefill-budget sharing the same way
    (nearest deadline drains its prompt first)."""

    name = "fifo+least-progress"

    @staticmethod
    def _deadline(req) -> float:
        d = getattr(req, "deadline", None)
        return float("inf") if d is None else d

    def next_index(self, queue: Sequence) -> Optional[int]:
        """Index into ``queue`` of the next admission candidate (EDF,
        then FIFO; None when empty). Head-of-line blocking is the
        engine's contract: if this request cannot be placed, nothing is."""
        if not queue:
            return None
        return min(range(len(queue)),
                   key=lambda i: (self._deadline(queue[i]), i))

    def prefill_key(self, req) -> Tuple:
        """Sort key for sharing the mixed step's prefill token budget
        between mid-prefill slots (ascending; ties broken by admission
        order in the engine): nearest deadline first."""
        return (self._deadline(req),)

    def pick_victim(self, candidates: List[Tuple[int, int, int]]) -> int:
        """Choose the slot to preempt from ``(slot, progress, priority)``
        triples, where progress counts generated tokens. Least progress
        first — cheapest to re-prefill — with the slot index as a
        deterministic tie-break (priority is ignored by this policy)."""
        if not candidates:
            raise ValueError("pick_victim needs at least one candidate")
        return min(candidates, key=lambda sp: (sp[1], sp[0]))[0]

    def requeue(self, queue: Deque, req) -> None:
        """Return a preempted request to the queue: at the FRONT, so FIFO
        order is preserved (it was admitted before anything now queued)."""
        queue.appendleft(req)

    def explain(self, req) -> Dict:
        """Admission-ordering fields for the request's trace (the engine
        stamps them onto the ``submitted`` span event): which policy saw
        the request and what key will order it."""
        d = self._deadline(req)
        out = {"policy": self.name}
        if d != float("inf"):
            out["deadline"] = d
        return out


class Priority(FifoLeastProgress):
    """Priority admission + lowest-priority preemption.

    Higher ``Request.priority`` admits first; within a priority class the
    earliest submission wins (stable FIFO). Preemption inverts it: the
    victim is the LOWEST-priority active slot, least-progress then slot
    index breaking ties — so pool pressure evicts background work before
    anything latency-sensitive, the first step toward the ROADMAP's
    gang/priority scheduling item."""

    name = "priority"

    def next_index(self, queue: Sequence) -> Optional[int]:
        if not queue:
            return None
        return min(range(len(queue)),
                   key=lambda i: (-queue[i].priority,
                                  self._deadline(queue[i]), i))

    def prefill_key(self, req) -> Tuple:
        """Priority class first, nearest deadline within it."""
        return (-req.priority, self._deadline(req))

    def pick_victim(self, candidates: List[Tuple[int, int, int]]) -> int:
        if not candidates:
            raise ValueError("pick_victim needs at least one candidate")
        return min(candidates, key=lambda c: (c[2], c[1], c[0]))[0]

    def requeue(self, queue: Deque, req) -> None:
        """Front of the queue: among equal priorities the preempted
        request was admitted first, and ``next_index`` already lets any
        higher-priority arrival jump it."""
        queue.appendleft(req)

    def explain(self, req) -> Dict:
        out = super().explain(req)
        out["priority"] = int(getattr(req, "priority", 0))
        return out
