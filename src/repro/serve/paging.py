"""Host-side page allocator for the paged KV cache (vLLM-style block tables).

The device pool (``models/kvcache.init_paged_kv``) is a flat array of
fixed-size pages; WHICH pages belong to WHICH slot is pure bookkeeping, so
it lives here on the host as a free-list over page ids. The engine reserves
pages at admission (worst-case ``ceil(ctx_cap / page_size)`` up front, or
just the prompt + one decode page under lazy growth) and returns every
reference when the request retires or is preempted.

Pages are REFERENCE-COUNTED so the prefix cache (serve/prefix.py) can share
one physical copy of a common prompt prefix across N requests:

  * ``alloc(owner, n, shared=pages)`` adopts already-live pages as the head
    of the owner's reservation (refcount += 1 each, zero fresh pages spent)
    and draws fresh pages (refcount 1) for the remainder;
  * ``ref``/``deref`` are raw references for a cache that keeps pages
    resident after their last owner retires (deref to 0 frees the page);
  * ``cow(owner, block)`` is the copy-on-write step: when a writer is about
    to extend into a page it shares (refcount > 1), the allocator swaps a
    fresh private page into the owner's table at that block and drops one
    reference on the shared original. (The DEVICE copy of the page's
    contents is the engine's job — ``models/kvcache.copy_page``.)

Invariants (property-tested in tests/test_paged_allocator.py against a
reference model, plus the hypothesis-free twin in tests/test_serve_paged.py):

  * refcount conservation: every live page's refcount equals the number of
    owners listing it plus the raw ``ref()`` count; pages_in_use equals the
    number of UNIQUE live pages (free + unique-live == pool);
  * ``free(owner)`` drops one reference per owned page — a page returns to
    the free-list exactly when its last reference drops (no double-free);
  * after ``cow`` the writer holds a refcount-1 private page and every
    other holder still sees the original;
  * without sharing ops the legacy exclusive-ownership behaviour is
    unchanged: ``pages_in_use == sum(ceil(len_i / page_size))``, and
    ``alloc``/``extend`` fail (None) exactly when the free-list is shorter
    than the request — never by fragmentation, because pages are uniform.

``extend`` on an unknown owner raises ``KeyError`` (it is a lookup error,
not a value error — and must never mint a fresh owner entry).

``rollback(owner, n_tokens)`` is ``extend``'s inverse for speculative
decode: tail pages beyond ``ceil(n_tokens / page_size)`` are released
(one reference each) and the owner's token length drops — the engine
calls it when the verifier rejects drafted tokens whose pages were
reserved optimistically.

Page id 0 is conventionally the NULL page (scratch rows for inactive
slots and bucket padding); construct with ``first_page=1`` to keep it out
of circulation.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Hashable, Iterable, List, Optional, Sequence


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (= ceil(n_tokens / page_size))."""
    if n_tokens < 0:
        raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
    return -(-n_tokens // page_size)


class PageAllocator:
    """Refcounted free-list allocator over ``num_pages`` uniform KV pages.

    Pure Python, O(pages moved) per call; owners are arbitrary hashable
    keys (the engine uses slot indices). A page may be listed by several
    owners (shared prefix) and/or held by raw ``ref()`` references (the
    prefix cache); it returns to the free-list when the last reference
    drops.
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 first_page: int = 0):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.first_page = first_page
        self._free: Deque[int] = deque(range(first_page,
                                             first_page + num_pages))
        self._owned: Dict[Hashable, List[int]] = {}
        self._len: Dict[Hashable, int] = {}
        self._ref: Dict[int, int] = {}        # live page -> reference count
        self._peak_owner = 0                  # high-water: pages/owner

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """UNIQUE live pages (a shared page counts once)."""
        return self.num_pages - len(self._free)

    def owners(self):
        return self._owned.keys()

    def pages_of(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def refcount(self, page: int) -> int:
        """References on a live page (0 for free pages)."""
        return self._ref.get(page, 0)

    def refcounts(self) -> Dict[int, int]:
        return dict(self._ref)

    def can_alloc(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page_size) <= len(self._free)

    @property
    def peak_owner_pages(self) -> int:
        """High-water mark of pages held by any SINGLE owner over the
        allocator's lifetime (monotone). This bounds how many page-table
        entries any slot has ever populated, so the engine's paged-
        attention gather only needs this many blocks — decode cost tracks
        occupancy, not the full table width (layers.paged_attention)."""
        return self._peak_owner

    # ----------------------------------------------------------- mutations
    def _take_fresh(self, n: int) -> List[int]:
        fresh = [self._free.popleft() for _ in range(n)]
        for p in fresh:
            self._ref[p] = 1
        return fresh

    def _drop(self, page: int):
        n = self._ref[page] - 1
        if n == 0:
            del self._ref[page]
            self._free.append(page)
        else:
            self._ref[page] = n

    def alloc(self, owner: Hashable, n_tokens: int, *,
              shared: Sequence[int] = ()) -> Optional[List[int]]:
        """Reserve pages covering ``n_tokens`` for ``owner``: adopt the
        ``shared`` pages (already-live pool pages, e.g. a prefix-cache hit,
        in block order) as the head of the reservation and draw fresh
        pages for the rest. Returns the full block-ordered page-id list,
        or None when the free-list cannot supply the fresh remainder (no
        references are taken — the caller keeps the request queued:
        admission backpressure, not an error)."""
        if owner in self._owned:
            raise ValueError(f"owner {owner!r} already holds pages; "
                             "free() it before re-allocating")
        shared = list(shared)
        for p in shared:
            if p not in self._ref:
                raise ValueError(f"shared page {p} is not live")
        need = pages_for(n_tokens, self.page_size) - len(shared)
        if need < 0:
            raise ValueError(
                f"owner {owner!r}: {len(shared)} shared pages exceed the "
                f"{pages_for(n_tokens, self.page_size)}-page reservation "
                f"for {n_tokens} tokens")
        if need > len(self._free):
            return None
        for p in shared:
            self._ref[p] += 1
        pages = shared + self._take_fresh(need)
        self._owned[owner] = pages
        self._len[owner] = n_tokens
        self._peak_owner = max(self._peak_owner, len(pages))
        return list(pages)

    def extend(self, owner: Hashable, n_tokens: int) -> Optional[List[int]]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` total.
        Returns the NEWLY added pages ([] if already covered), or None if
        the free-list cannot supply them (reservation unchanged). Raises
        KeyError for an owner that holds no pages — extend must never mint
        a fresh owner entry."""
        if owner not in self._owned:
            raise KeyError(f"owner {owner!r} holds no pages")
        if n_tokens < self._len[owner]:
            raise ValueError(
                f"owner {owner!r}: cannot shrink {self._len[owner]} -> "
                f"{n_tokens} tokens (pages are only released by free())")
        need = pages_for(n_tokens, self.page_size) - len(self._owned[owner])
        if need > len(self._free):
            return None
        fresh = self._take_fresh(max(need, 0))
        self._owned[owner].extend(fresh)
        self._len[owner] = n_tokens
        self._peak_owner = max(self._peak_owner, len(self._owned[owner]))
        return fresh

    def rollback(self, owner: Hashable, n_tokens: int) -> List[int]:
        """Shrink ``owner``'s reservation back to cover ``n_tokens``
        total — the speculative-decode rejection path: draft pages
        reserved for tokens the verifier rejected are returned, tail
        first. Drops one reference per released tail page (a shared
        page stays live for its other holders) and returns the pages
        removed from the owner's table ([] when the reservation already
        fits) so the engine can null their page-table entries. Unlike
        ``free`` this never releases pages the accepted context still
        needs; unlike ``extend`` it may lower the owner's token length
        (``extend``'s no-shrink rule guards against accidental loss —
        rollback IS the deliberate loss). ``peak_owner_pages`` stays
        monotone: the bounded-gather bucket never shrinks mid-decode."""
        if owner not in self._owned:
            raise KeyError(f"owner {owner!r} holds no pages")
        if n_tokens > self._len[owner]:
            raise ValueError(
                f"owner {owner!r}: rollback to {n_tokens} tokens exceeds "
                f"the {self._len[owner]}-token reservation (use extend)")
        pages = self._owned[owner]
        keep = pages_for(n_tokens, self.page_size)
        dropped = pages[keep:]
        del pages[keep:]
        for p in dropped:
            self._drop(p)
        self._len[owner] = n_tokens
        return dropped

    def cow(self, owner: Hashable, block: int) -> Optional[int]:
        """Copy-on-write: give ``owner`` a PRIVATE page at table index
        ``block``. If the page there is unshared (refcount 1) it is
        returned as-is; otherwise a fresh page replaces it in the owner's
        list (refcount 1) and one reference is dropped from the shared
        original. Returns None when no fresh page is free (owner
        unchanged). The caller copies the page CONTENTS on device."""
        if owner not in self._owned:
            raise KeyError(f"owner {owner!r} holds no pages")
        pages = self._owned[owner]
        if not 0 <= block < len(pages):
            raise ValueError(f"owner {owner!r}: block {block} outside its "
                             f"{len(pages)}-page table")
        old = pages[block]
        if self._ref[old] == 1:
            return old
        if not self._free:
            return None
        [new] = self._take_fresh(1)
        self._ref[old] -= 1          # shared: never drops to 0 here
        pages[block] = new
        return new

    def ref(self, page: int):
        """Take a raw reference on a live page (the prefix cache pinning a
        registered block)."""
        if page not in self._ref:
            raise KeyError(f"page {page} is not live")
        self._ref[page] += 1

    def deref(self, page: int):
        """Drop a raw reference; the page returns to the free-list when
        its last reference drops."""
        if page not in self._ref:
            raise KeyError(f"page {page} is not live")
        self._drop(page)

    def free(self, owner: Hashable) -> List[int]:
        """Drop one reference on each of ``owner``'s pages (shared pages
        stay live for their other holders). Returns the owner's page
        list."""
        pages = self._owned.pop(owner, None)
        if pages is None:
            raise ValueError(f"owner {owner!r} holds no pages")
        del self._len[owner]
        for p in pages:
            self._drop(p)
        return pages
