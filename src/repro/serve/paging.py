"""Host-side page allocator for the paged KV cache (vLLM-style block tables).

The device pool (``models/kvcache.init_paged_kv``) is a flat array of
fixed-size pages; WHICH pages belong to WHICH slot is pure bookkeeping, so
it lives here on the host as a free-list over page ids. The engine reserves
a slot's worst-case page count at admission (``ceil(ctx_cap / page_size)``,
where ``ctx_cap = min(prompt + max_new - 1, max_len)``) and returns every
page to the free-list when the request retires — no page is ever shared by
two live slots, and no copy/compaction ever moves a page.

Invariants (the property-test suite in tests/test_paged_allocator.py
churns random admission/extend/free sequences against a reference model):

  * a page is owned by at most one live owner at a time;
  * ``free(owner)`` returns ALL of the owner's pages to the free-list;
  * ``pages_in_use == sum(ceil(len_i / page_size))`` over live owners;
  * ``alloc`` fails (returns None) exactly when the free-list is shorter
    than the request — never by fragmentation, because pages are uniform.

Page id 0 is conventionally the NULL page (scratch rows for inactive
slots and bucket padding); construct with ``first_page=1`` to keep it out
of circulation.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Hashable, List, Optional


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (= ceil(n_tokens / page_size))."""
    if n_tokens < 0:
        raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
    return -(-n_tokens // page_size)


class PageAllocator:
    """Free-list allocator over ``num_pages`` uniform KV pages.

    Pure Python, O(pages moved) per call; owners are arbitrary hashable
    keys (the engine uses slot indices).
    """

    def __init__(self, num_pages: int, page_size: int, *,
                 first_page: int = 0):
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = num_pages
        self.page_size = page_size
        self.first_page = first_page
        self._free: Deque[int] = deque(range(first_page,
                                             first_page + num_pages))
        self._owned: Dict[Hashable, List[int]] = {}
        self._len: Dict[Hashable, int] = {}

    # ------------------------------------------------------------- queries
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def owners(self):
        return self._owned.keys()

    def pages_of(self, owner: Hashable) -> List[int]:
        return list(self._owned.get(owner, ()))

    def can_alloc(self, n_tokens: int) -> bool:
        return pages_for(n_tokens, self.page_size) <= len(self._free)

    # ----------------------------------------------------------- mutations
    def alloc(self, owner: Hashable, n_tokens: int) -> Optional[List[int]]:
        """Reserve pages covering ``n_tokens`` for ``owner``. Returns the
        page-id list, or None when the free-list is too short (the caller
        keeps the request queued — admission backpressure, not an error)."""
        if owner in self._owned:
            raise ValueError(f"owner {owner!r} already holds pages; "
                             "free() it before re-allocating")
        need = pages_for(n_tokens, self.page_size)
        if need > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(need)]
        self._owned[owner] = pages
        self._len[owner] = n_tokens
        return list(pages)

    def extend(self, owner: Hashable, n_tokens: int) -> Optional[List[int]]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` total.
        Returns the NEWLY added pages ([] if already covered), or None if
        the free-list cannot supply them (reservation unchanged)."""
        if owner not in self._owned:
            raise ValueError(f"owner {owner!r} holds no pages")
        if n_tokens < self._len[owner]:
            raise ValueError(
                f"owner {owner!r}: cannot shrink {self._len[owner]} -> "
                f"{n_tokens} tokens (pages are only released by free())")
        need = pages_for(n_tokens, self.page_size) - len(self._owned[owner])
        if need > len(self._free):
            return None
        fresh = [self._free.popleft() for _ in range(need)]
        self._owned[owner].extend(fresh)
        self._len[owner] = n_tokens
        return fresh

    def free(self, owner: Hashable) -> List[int]:
        """Return ALL of ``owner``'s pages to the free-list."""
        pages = self._owned.pop(owner, None)
        if pages is None:
            raise ValueError(f"owner {owner!r} holds no pages")
        del self._len[owner]
        self._free.extend(pages)
        return pages
