"""Serving steps: prefill and single-token decode (the dry-run targets for
prefill_32k / decode_32k / long_500k), prompt-length bucketing, the
page-wise prefill scatter for the engine's paged KV layout, and the
greedy/sampled generate loop."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.sampling import sample_tokens

MIN_PREFILL_BUCKET = 8


def prefill_bucket(n: int, *, cap: int = 0,
                   min_bucket: int = MIN_PREFILL_BUCKET) -> int:
    """Padded prompt length for an ``n``-token prompt: the smallest
    power of two >= max(n, min_bucket).

    Bucketing bounds the number of distinct prefill shapes — and therefore
    XLA retraces — to log2(max_len) instead of one per prompt length.
    ``cap`` > 0 bounds the padded length (the cache window); when even the
    bucket would overflow it, fall back to the exact length so the cache
    layout stays consistent (``kvcache.fit_prefill`` would otherwise keep
    padding rows and drop real ones).
    """
    assert n >= 1
    b = max(n, min_bucket)
    b = 1 << (b - 1).bit_length()
    if cap > 0 and b > cap:
        return n
    return b


def page_bucket(n_blocks: int, *, cap: int) -> int:
    """Bucketed page-table width for the engine's bounded paged-attention
    gather: the smallest power of two >= ``n_blocks`` (the allocator's
    per-owner page high-water mark), clipped to ``cap`` (the full table
    width, ``pages_for(max_len)``). Bucketing means the decode program
    only retraces when occupancy crosses a power-of-two block boundary —
    cost tracks the pool's live high-water mark, not ``max_len``, while
    the one-decode-trace property holds between re-bucketings."""
    assert n_blocks >= 1 and cap >= 1
    return min(cap, 1 << (n_blocks - 1).bit_length())


def pack_token_budget(budget: int, decode_rows, prefill_items):
    """Fill one mixed step's token budget: decode first, then prefill
    chunks in the given order (the scheduler's priority order).

    ``decode_rows`` is either the total decode row count (the classic
    one-row-per-slot step) or a sequence of PER-SLOT row counts — the
    speculative-decode hook: a slot verifying ``k`` drafted tokens
    occupies ``1 + k`` rows (its base decode row plus the draft rows),
    and every one of them is reserved ahead of prefill. Only the sum
    matters to the packing; the sequence form exists so callers state
    per-slot demand directly and the property tests can pin that drafted
    rows are never displaced.

    ``prefill_items`` are dicts with ``slot``, ``cursor`` (prompt tokens
    already prefilled), ``n`` (total prompt tokens) and optional ``dep``
    — a ``(donor_slot, needed_tokens)`` pair meaning this item adopted
    the donor's shared pages up to ``needed_tokens`` and must not run a
    chunk until the donor's PLANNED coverage (its cursor after this
    step's allotments) reaches that point; same-step coverage counts
    because the mixed program scatters every chunk's KV before any token
    attends (serve/engine._mixed_fn).

    Returns ``[(slot, start, count), ...]`` with ``count >= 1``,
    ``sum(count) <= budget - sum(decode_rows)``. Decode (and draft) rows
    are reserved FIRST — prefill never displaces them — and a step whose
    decode demand alone exceeds the budget is a sizing bug, so it
    raises. Pure host logic; the hypothesis suite in
    tests/test_serve_mixed.py drives it across random mixes.
    """
    n_decode = decode_rows if isinstance(decode_rows, int) \
        else sum(decode_rows)
    if n_decode > budget:
        raise ValueError(
            f"decode demand {n_decode} exceeds the token budget {budget}; "
            "chunk_tokens must be >= the slot count")
    left = budget - n_decode
    planned_end = {it["slot"]: it["cursor"] for it in prefill_items}
    allot = []
    for it in prefill_items:
        if left <= 0:
            break
        dep = it.get("dep")
        if dep is not None:
            donor, needed = dep
            if planned_end.get(donor, needed) < needed:
                continue
        take = min(left, it["n"] - it["cursor"])
        if take <= 0:
            continue
        allot.append((it["slot"], it["cursor"], take))
        planned_end[it["slot"]] = it["cursor"] + take
        left -= take
    return allot


def scatter_prefill_pages(pool, kvs, pages, page_size: int):
    """Write a freshly-prefilled per-request KV into its pool pages.

    pool leaves: (L, n_pages, page_size, Hkv, D) — the engine's shared
    page pool. kvs leaves: (L, 1, S, Hkv, D) with S a multiple of
    ``page_size`` (the prefill cache is sized to whole pages). pages:
    (S // page_size,) pool indices — entries beyond the slot's reservation
    are the null page 0, so bucket padding lands in scratch instead of a
    neighbour's page.
    """
    def put(pool_leaf, kv_leaf):
        l, _, s, h, d = kv_leaf.shape
        tiles = kv_leaf.reshape(l, s // page_size, page_size, h, d)
        return pool_leaf.at[:, pages].set(tiles)

    return jax.tree.map(put, pool, kvs)


def make_prefill_step(cfg, strategy: Strategy) -> Callable:
    model = get_model(cfg)
    n_micro = strategy.microbatches

    def one(params, batch):
        cache = model.init_cache(cfg, batch["tokens"].shape[0],
                                 batch["tokens"].shape[1])
        return model.prefill(params, batch, cfg, cache,
                             attn_impl=strategy.attn_impl)

    def prefill_step(params, batch):
        b = batch["tokens"].shape[0]
        if n_micro <= 1 or b % n_micro != 0:
            return one(params, batch)
        # batch-chunked prefill: bounds the transient activation /
        # MoE-dispatch working set to one chunk (beyond-paper; §Perf).
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, b // n_micro, *x.shape[1:]), batch)
        logits, caches = jax.lax.map(lambda mb: one(params, mb), micro)
        # (n, ..., b/n, ...) -> merge the chunked batch dim (dim 0 of
        # logits; dim 1 of stacked (L, b, ...) cache leaves; pos is scalar)
        logits = logits.reshape(b, *logits.shape[2:])

        def merge(leaf):
            if leaf.ndim <= 1:          # pos scalars: identical per chunk
                return leaf[0]
            # (n, L, b/n, ...) -> (L, n, b/n, ...) -> (L, b, ...)
            moved = jnp.moveaxis(leaf, 0, 1)
            return moved.reshape(moved.shape[0], b, *moved.shape[3:])

        cache = jax.tree.map(merge, caches)
        return logits, cache

    return prefill_step


def make_decode_step(cfg, strategy: Strategy) -> Callable:
    """serve_step: ONE new token against a cache of seq_len entries."""
    model = get_model(cfg)

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos, cfg)

    return decode_step


def greedy_generate(params, cfg, strategy, prompt, steps: int, *,
                    temperature: float = 0.0,
                    rng: Optional[jax.Array] = None):
    """Simple lockstep generate loop used by examples/tests (jit per step).

    Greedy by default; ``temperature > 0`` (+ ``rng``) samples through the
    same on-device hook the serve engine uses (serve/sampling.py)."""
    model = get_model(cfg)
    b, s = prompt["tokens"].shape
    cache = model.init_cache(cfg, b, s + steps)
    logits, cache = model.prefill(params, prompt, cfg, cache)
    keys = (jax.random.split(rng, steps) if rng is not None
            else [None] * steps)
    tok = sample_tokens(logits[:, -1], rng=keys[0],
                        temperature=temperature)[:, None]
    out = [tok]
    step_fn = jax.jit(
        lambda p_, c, t, i, k: _sampled_decode(model, cfg, p_, c, t, i, k,
                                               temperature))
    for i in range(steps - 1):
        tok, cache = step_fn(params, cache, tok,
                             jnp.asarray(s + i, jnp.int32), keys[i + 1])
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _sampled_decode(model, cfg, params, cache, tok, pos, rng, temperature):
    logits, cache = model.decode_step(params, cache, tok, pos, cfg)
    return sample_tokens(logits[:, -1], rng=rng,
                         temperature=temperature)[:, None], cache
