"""Serving steps: prefill and single-token decode (the dry-run targets for
prefill_32k / decode_32k / long_500k)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.strategy import Strategy
from repro.models import get_model


def make_prefill_step(cfg, strategy: Strategy) -> Callable:
    model = get_model(cfg)
    n_micro = strategy.microbatches

    def one(params, batch):
        cache = model.init_cache(cfg, batch["tokens"].shape[0],
                                 batch["tokens"].shape[1])
        return model.prefill(params, batch, cfg, cache,
                             attn_impl=strategy.attn_impl)

    def prefill_step(params, batch):
        b = batch["tokens"].shape[0]
        if n_micro <= 1 or b % n_micro != 0:
            return one(params, batch)
        # batch-chunked prefill: bounds the transient activation /
        # MoE-dispatch working set to one chunk (beyond-paper; §Perf).
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, b // n_micro, *x.shape[1:]), batch)
        logits, caches = jax.lax.map(lambda mb: one(params, mb), micro)
        # (n, ..., b/n, ...) -> merge the chunked batch dim (dim 0 of
        # logits; dim 1 of stacked (L, b, ...) cache leaves; pos is scalar)
        logits = logits.reshape(b, *logits.shape[2:])

        def merge(leaf):
            if leaf.ndim <= 1:          # pos scalars: identical per chunk
                return leaf[0]
            # (n, L, b/n, ...) -> (L, n, b/n, ...) -> (L, b, ...)
            moved = jnp.moveaxis(leaf, 0, 1)
            return moved.reshape(moved.shape[0], b, *moved.shape[3:])

        cache = jax.tree.map(merge, caches)
        return logits, cache

    return prefill_step


def make_decode_step(cfg, strategy: Strategy) -> Callable:
    """serve_step: ONE new token against a cache of seq_len entries."""
    model = get_model(cfg)

    def decode_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos, cfg)

    return decode_step


def greedy_generate(params, cfg, strategy, prompt, steps: int):
    """Simple greedy loop used by examples/tests (jit per step)."""
    model = get_model(cfg)
    b, s = prompt["tokens"].shape
    cache = model.init_cache(cfg, b, s + steps)
    logits, cache = model.prefill(params, prompt, cfg, cache)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    step_fn = jax.jit(lambda p_, c, t, i: model.decode_step(p_, c, t, i, cfg))
    for i in range(steps - 1):
        logits, cache = step_fn(params, cache, tok,
                                jnp.asarray(s + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
