"""Dependency-free serving metrics: counters, gauges, quantile histograms.

The survey's systems thread (Nagrecha 2023) is blunt about it: parallel
execution is only half a serving system — the other half is the
operational machinery that tells you whether it is actually serving.
This module is that half's measurement layer, deliberately free of any
client library so it imports anywhere the engine does:

  * :class:`Counter` — monotone float/int accumulator (``inc``);
  * :class:`Gauge` — last-write-wins instantaneous value (``set``);
  * :class:`Histogram` — streaming observations with exact ``count`` /
    ``sum`` and windowed p50/p90/p99 quantiles over the most recent
    ``window`` samples (a bounded deque, so a long-lived server never
    grows without bound; the window is large enough that steady-state
    percentiles are stable). Rendered as a Prometheus ``summary``.
  * :class:`MetricsRegistry` — names -> instruments, rendered in the
    Prometheus text exposition format (``GET /metrics`` serves exactly
    :meth:`MetricsRegistry.render`'s output).
  * :class:`ServeMetrics` — the serving-specific facade the AsyncDriver
    records into: per-request TTFT (submit -> first token) and TPOT
    (inter-token gap after the first), per-step latency and batch
    occupancy, stream/watchdog counters, plus a snapshot hook that
    exports ``engine.stats`` (pool/prefix/preemption telemetry) as
    gauges at scrape time.

Every instrument is thread-safe (one lock each): the driver loop records
while the HTTP scrape thread renders.

Metric glossary (the names ``GET /metrics`` exposes):

  ``serve_ttft_seconds``            summary   submit -> first streamed token
  ``serve_tpot_seconds``            summary   gap between consecutive tokens
  ``serve_e2e_seconds``             summary   submit -> request completion
  ``serve_step_seconds``            summary   one engine step, wall time
  ``serve_step_occupancy``          summary   active slots entering a step
  ``serve_step_phase_seconds``      summary   host time one step spent in
                                              each phase, labelled
                                              ``{phase="bookkeeping|draft|
                                              pack|dispatch|sync|admit"}``
                                              (fed from the tracer's
                                              per-step phase laps)
  ``serve_prefill_chunk_tokens``    summary   prefill tokens one mixed step
                                              processed as chunks (0 on
                                              pure-decode steps; bounded by
                                              the engine's ``chunk_tokens``
                                              budget)
  ``serve_step_prefill_fraction``   summary   prefill share of a mixed
                                              step's work items —
                                              chunk tokens over chunk
                                              tokens + decode tokens
  ``serve_requests_submitted_total``  counter
  ``serve_requests_completed_total``  counter
  ``serve_requests_expired_total``    counter deadline passed while queued
                                              (done=False, expired=True)
  ``serve_tokens_streamed_total``     counter streamed tokens (all requests)
  ``serve_watchdog_fired_total``      counter stalled-step detections
  ``serve_watchdog_requeued_total``   counter requests requeued by recovery
  ``serve_spec_drafted_total``        counter speculative tokens drafted
                                              (verify rows packed into
                                              mixed steps)
  ``serve_spec_accepted_total``       counter drafted tokens the verifier
                                              accepted (greedy prefix
                                              match; bonus tokens not
                                              counted — they are ordinary
                                              decode output)
  ``serve_queue_depth``             gauge     queued requests right now
  ``serve_active_slots``            gauge     occupied slots right now
  ``serve_spec_accept_rate``        gauge     cumulative accepted/drafted
                                              (0 until something drafts)
  ``serve_spec_tokens_per_step``    summary   decode tokens emitted per
                                              decode step (prefill-sampled
                                              first tokens excluded);
                                              > 1.0 is speculation paying
  ``serve_engine_<stat>``           gauge     every numeric ``engine.stats``
                                              field (pages_in_use,
                                              peak_pages, prefix_* ,
                                              preemptions, cow_copies,
                                              decode_steps, step_count,
                                              decode_tokens, wall_time_s,
                                              tokens_per_s_ewma, ...);
                                              string fields export info-
                                              style — decode_backend
                                              (the engine's paged-
                                              attention path) becomes
                                              ``serve_engine_decode_backend
                                              {value="gather|pallas"} 1.0``
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: the quantiles every summary exports (the TTFT/TPOT acceptance set)
QUANTILES = (0.5, 0.9, 0.99)


def quantile(sorted_values: Sequence[float], q: float) -> float:
    """Linear-interpolation quantile of an ascending-sorted sequence
    (numpy's default method, dependency-free). NaN on empty input; the
    single sample for any ``q`` on one-element input."""
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    n = len(sorted_values)
    if n == 0:
        return float("nan")
    if n == 1:
        return float(sorted_values[0])
    pos = q * (n - 1)
    lo = int(pos)
    hi = min(lo + 1, n - 1)
    frac = pos - lo
    return float(sorted_values[lo]) * (1.0 - frac) \
        + float(sorted_values[hi]) * frac


def _fmt(v: float) -> str:
    """Prometheus sample value: plain float, NaN spelled ``NaN``."""
    if v != v:                      # NaN
        return "NaN"
    return repr(float(v))


class Counter:
    """Monotone accumulator. ``inc`` by any non-negative amount."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0):
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up "
                             f"(inc {amount})")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self):
        with self._lock:
            self._value = 0.0

    def render(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Gauge:
    """Instantaneous value; ``set`` overwrites."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float):
        with self._lock:
            self._value = float(value)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def reset(self):
        self.set(0.0)

    def render(self) -> List[str]:
        return [f"{self.name} {_fmt(self.value)}"]


class Histogram:
    """Quantile histogram: exact count/sum, windowed percentiles.

    Keeps the most recent ``window`` observations (bounded memory for a
    long-lived server); ``quantile``/``quantiles`` sort the window on
    demand — scrapes are rare next to observations, so the cost sits on
    the scrape path. Rendered as a Prometheus ``summary`` with the
    :data:`QUANTILES` labels plus ``_sum``/``_count`` series.
    """

    kind = "summary"

    def __init__(self, name: str, help: str = "", *, window: int = 4096):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.name, self.help = name, help
        self.window = window
        self._lock = threading.Lock()
        self._samples: deque = deque(maxlen=window)
        self._count = 0
        self._sum = 0.0

    def observe(self, value: float):
        with self._lock:
            self._samples.append(float(value))
            self._count += 1
            self._sum += float(value)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def quantile(self, q: float) -> float:
        return self.quantiles([q])[0]

    def quantiles(self, qs: Iterable[float] = QUANTILES) -> List[float]:
        """One sort, many quantiles — NaN-filled when no samples yet."""
        with self._lock:
            window = sorted(self._samples)
        return [quantile(window, q) for q in qs]

    def snapshot(self) -> Tuple[List[float], float, int]:
        """(sorted window, sum, count) captured under ONE lock
        acquisition, so a render's quantiles and its ``_sum``/``_count``
        lines describe the same instant even while another thread
        observes concurrently."""
        with self._lock:
            return sorted(self._samples), self._sum, self._count

    def reset(self):
        with self._lock:
            self._samples.clear()
            self._count = 0
            self._sum = 0.0

    def render(self) -> List[str]:
        window, total, count = self.snapshot()
        lines = [f'{self.name}{{quantile="{q}"}} {_fmt(quantile(window, q))}'
                 for q in QUANTILES]
        lines.append(f"{self.name}_sum {_fmt(total)}")
        lines.append(f"{self.name}_count {count}")
        return lines


class LabeledHistogram:
    """A family of :class:`Histogram` children keyed by one label value
    (e.g. ``serve_step_phase_seconds{phase="dispatch"}``): one registered
    name, one ``TYPE summary`` header, per-label quantile/sum/count
    series. Children are created on first ``observe`` — label sets are
    small and bounded by the caller (the engine's phase names)."""

    kind = "summary"

    def __init__(self, name: str, help: str = "", *, label: str = "label",
                 window: int = 4096):
        self.name, self.help = name, help
        self.label = label
        self.window = window
        self._lock = threading.Lock()
        self._children: Dict[str, Histogram] = {}

    def child(self, value: str) -> Histogram:
        value = str(value)
        with self._lock:
            h = self._children.get(value)
            if h is None:
                h = self._children[value] = Histogram(
                    self.name, window=self.window)
            return h

    def observe(self, label_value: str, value: float):
        self.child(label_value).observe(value)

    def labels(self) -> List[str]:
        with self._lock:
            return sorted(self._children)

    def reset(self):
        with self._lock:
            children = list(self._children.values())
        for h in children:
            h.reset()

    def render(self) -> List[str]:
        with self._lock:
            items = sorted(self._children.items())
        lines: List[str] = []
        for lv, h in items:
            window, total, count = h.snapshot()
            for q in QUANTILES:
                lines.append(
                    f'{self.name}{{{self.label}="{lv}",quantile="{q}"}} '
                    f'{_fmt(quantile(window, q))}')
            lines.append(
                f'{self.name}_sum{{{self.label}="{lv}"}} {_fmt(total)}')
            lines.append(
                f'{self.name}_count{{{self.label}="{lv}"}} {count}')
        return lines


class MetricsRegistry:
    """Ordered name -> instrument map with Prometheus text rendering."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _register(self, metric):
        with self._lock:
            if metric.name in self._metrics:
                raise ValueError(f"metric {metric.name!r} already "
                                 "registered")
            self._metrics[metric.name] = metric
        return metric

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter(name, help))

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge(name, help))

    def histogram(self, name: str, help: str = "", *,
                  window: int = 4096) -> Histogram:
        return self._register(Histogram(name, help, window=window))

    def labeled_histogram(self, name: str, help: str = "", *,
                          label: str = "label",
                          window: int = 4096) -> LabeledHistogram:
        return self._register(
            LabeledHistogram(name, help, label=label, window=window))

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def reset(self):
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4): HELP/TYPE
        headers then the samples, one instrument after another."""
        with self._lock:
            metrics = list(self._metrics.values())
        out: List[str] = []
        for m in metrics:
            if m.help:
                out.append(f"# HELP {m.name} {m.help}")
            out.append(f"# TYPE {m.name} {m.kind}")
            out.extend(m.render())
        return "\n".join(out) + "\n"


class ServeMetrics:
    """The serving facade: every instrument the AsyncDriver records.

    ``render(extra=engine.stats)`` additionally exports each numeric
    stats field as a ``serve_engine_<name>`` gauge, so one scrape carries
    the latency picture AND the pool/prefix/preemption telemetry the
    engine already keeps. String fields (``decode_backend``) export
    info-style — ``serve_engine_decode_backend{value="pallas"} 1.0``;
    other non-numeric fields (the router's per-replica breakdown list)
    are skipped; per-replica detail stays available via ``stats``
    itself.
    """

    def __init__(self, *, window: int = 4096):
        r = self.registry = MetricsRegistry()
        self.ttft = r.histogram(
            "serve_ttft_seconds",
            "Time from submit to the request's first streamed token",
            window=window)
        self.tpot = r.histogram(
            "serve_tpot_seconds",
            "Gap between a request's consecutive streamed tokens",
            window=window)
        self.e2e = r.histogram(
            "serve_e2e_seconds",
            "Time from submit to request completion", window=window)
        self.step_latency = r.histogram(
            "serve_step_seconds", "Engine step wall time", window=window)
        self.occupancy = r.histogram(
            "serve_step_occupancy",
            "Active slots entering each engine step", window=window)
        self.step_phase = r.labeled_histogram(
            "serve_step_phase_seconds",
            "Host-side time one engine step spent in each phase "
            "(bookkeeping/draft/pack/dispatch/sync; legacy adds admit)",
            label="phase", window=window)
        self.prefill_chunk = r.histogram(
            "serve_prefill_chunk_tokens",
            "Prefill tokens processed as chunks by one mixed step",
            window=window)
        self.prefill_frac = r.histogram(
            "serve_step_prefill_fraction",
            "Prefill share of one mixed step's processed tokens",
            window=window)
        self.submitted = r.counter(
            "serve_requests_submitted_total", "Requests accepted")
        self.completed = r.counter(
            "serve_requests_completed_total", "Requests completed")
        self.expired = r.counter(
            "serve_requests_expired_total",
            "Requests whose deadline passed while still queued")
        self.tokens = r.counter(
            "serve_tokens_streamed_total", "Tokens streamed to requests")
        self.watchdog_fired = r.counter(
            "serve_watchdog_fired_total",
            "Stalled/over-deadline steps the watchdog detected")
        self.watchdog_requeued = r.counter(
            "serve_watchdog_requeued_total",
            "Requests cancelled-and-requeued by watchdog recovery")
        self.spec_drafted = r.counter(
            "serve_spec_drafted_total",
            "Speculative tokens drafted (verify rows packed)")
        self.spec_accepted = r.counter(
            "serve_spec_accepted_total",
            "Drafted tokens the verifier accepted")
        self.spec_accept_rate = r.gauge(
            "serve_spec_accept_rate",
            "Cumulative speculative accept rate (accepted / drafted)")
        self.spec_tokens_per_step = r.histogram(
            "serve_spec_tokens_per_step",
            "Decode tokens emitted per decode step under speculation",
            window=window)
        self.queue_depth = r.gauge(
            "serve_queue_depth", "Requests queued right now")
        self.active_slots = r.gauge(
            "serve_active_slots", "Slots decoding right now")
        self._extra_lock = threading.Lock()
        self._extra_gauges: Dict[str, Gauge] = {}

    # ------------------------------------------------------------ summary
    def latency_summary(self) -> Dict[str, float]:
        """The benchmark row: TTFT/TPOT p50/p90/p99 (seconds)."""
        out: Dict[str, float] = {}
        for label, hist in (("ttft", self.ttft), ("tpot", self.tpot)):
            for q, v in zip(QUANTILES, hist.quantiles(QUANTILES)):
                out[f"{label}_p{int(q * 100)}_s"] = v
        return out

    # ------------------------------------------------------------- render
    def render(self, extra: Optional[Dict] = None) -> str:
        """Prometheus text: the driver instruments plus, when ``extra``
        (an ``engine.stats`` dict) is given, one ``serve_engine_<k>``
        gauge per numeric field."""
        text = self.registry.render()
        if not extra:
            return text
        lines: List[str] = []
        for key, value in extra.items():
            name = f"serve_engine_{key}"
            if isinstance(value, str):
                # identity fields (decode_backend) export Prometheus
                # info-style: constant 1 with the value as a label
                lines.append(f"# TYPE {name} gauge")
                lines.append(f'{name}{{value="{value}"}} 1.0')
                continue
            if isinstance(value, bool) or not isinstance(value,
                                                         (int, float)):
                continue
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {_fmt(float(value))}")
        return text + "\n".join(lines) + ("\n" if lines else "")
