"""Whisper-style encoder-decoder (audio). The mel+conv frontend is STUBBED:
``batch["frames"]`` carries precomputed (B, encoder_ctx, d_model) frame
embeddings (the assignment's one allowed stub). Sinusoidal positions,
bidirectional encoder, causal decoder with cross-attention, plain-GeLU MLPs
(as in Whisper).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pspec import constrain
from repro.models import kvcache
from repro.models.layers import (attention, attn_out, attn_qkv, dense_init,
                                 init_attn, init_mlp, mlp, paged_attention,
                                 rmsnorm)


def sinusoid(length: int, dim: int):
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / dim)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], -1),
                       jnp.float32)


def init_enc_layer(key, cfg):
    ka, km = jax.random.split(key)
    return {"attn": init_attn(ka, cfg), "mlp": init_mlp(km, cfg, gated=False),
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32)}


def init_dec_layer(key, cfg):
    ka, kc, km = jax.random.split(key, 3)
    return {"attn": init_attn(ka, cfg), "xattn": init_attn(kc, cfg),
            "mlp": init_mlp(km, cfg, gated=False),
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "lnx": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32)}


def init(key, cfg):
    ke, kd, kt, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.encoder_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.num_layers))
    return {
        "tok_embed": dense_init(kt, (cfg.vocab_size, cfg.d_model),
                                jnp.dtype(cfg.dtype)),
        "enc_layers": enc, "dec_layers": dec,
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size),
                              jnp.dtype(cfg.dtype)),
    }


def encode(params, frames, cfg, *, attn_impl="auto"):
    """frames: (B, enc_ctx, d_model) stub embeddings -> (B, enc_ctx, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    x = constrain(x, "batch", None, None)

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg, rope=False)
        ctx = attention(q, k, v, causal=False, impl=attn_impl)
        x = x + attn_out(lp["attn"], ctx, cfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross(lp, x, enc_kv, cfg):
    """Cross-attention with precomputed encoder K/V (B,T,Hkv,D)."""
    h = rmsnorm(x, lp["lnx"], cfg.norm_eps)
    b, s, _ = h.shape
    q = (h @ lp["xattn"]["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    ctx = attention(q, enc_kv["k"], enc_kv["v"], causal=False, impl="full")
    return x + attn_out(lp["xattn"], ctx, cfg)


def cross_kv(lp, enc_out, cfg):
    b, t, _ = enc_out.shape
    k = (enc_out @ lp["xattn"]["wk"]).reshape(b, t, cfg.num_kv_heads,
                                              cfg.head_dim)
    v = (enc_out @ lp["xattn"]["wv"]).reshape(b, t, cfg.num_kv_heads,
                                              cfg.head_dim)
    return {"k": k, "v": v}


def forward(params, batch, cfg, *, remat: bool = False, attn_impl="auto"):
    """batch: {"tokens": (B,S), "frames": (B,enc_ctx,d)} -> dec logits."""
    enc_out = encode(params, batch["frames"], cfg, attn_impl=attn_impl)
    tokens = batch["tokens"]
    x = params["tok_embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg, rope=False)
        ctx = attention(q, k, v, causal=True, impl=attn_impl)
        x = x + attn_out(lp["attn"], ctx, cfg)
        x = _cross(lp, x, cross_kv(lp, enc_out, cfg), cfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = constrain(x @ params["lm_head"], "batch", None, "vocab")
    return logits, jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    kv = kvcache.init_kv(batch, max_len, cfg.num_kv_heads, cfg.head_dim,
                         dtype)
    xkv = kvcache.init_kv(batch, cfg.encoder_ctx, cfg.num_kv_heads,
                          cfg.head_dim, dtype)
    stack = lambda t: jax.tree.map(
        lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), t)
    return {"kv": stack(kv), "xkv": stack(xkv),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, cache, *, attn_impl="auto"):
    """Encode frames, precompute per-layer cross K/V, run prompt tokens."""
    enc_out = encode(params, batch["frames"], cfg, attn_impl=attn_impl)
    tokens = batch["tokens"]
    s = tokens.shape[1]
    w = cache["kv"]["k"].shape[2]
    x = params["tok_embed"][tokens].astype(jnp.dtype(cfg.dtype))
    x = x + sinusoid(s, cfg.d_model).astype(x.dtype)

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg, rope=False)
        ctx = attention(q, k, v, causal=True, impl=attn_impl)
        x = x + attn_out(lp["attn"], ctx, cfg)
        xkv = cross_kv(lp, enc_out, cfg)
        x = _cross(lp, x, xkv, cfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, ({"k": kvcache.fit_prefill(k, w), "v": kvcache.fit_prefill(v, w)}, xkv)

    x, (kvs, xkvs) = jax.lax.scan(body, x, params["dec_layers"])
    cache = {"kv": kvs, "xkv": xkvs, "pos": jnp.asarray(s, jnp.int32)}
    x = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return x @ params["lm_head"], cache


def decode_step(params, cache, token, pos, cfg, *,
                attn_backend: str = "gather"):
    """``pos``: scalar (lockstep) or (B,) per-row vector (slot-table).

    With a ``"ptab"`` page table in the cache (the serve engine's paged
    layout) the decoder self-attention KV goes through the block-table
    path; the cross-attention KV stays a dense per-slot block — its length
    is the FIXED encoder context, so paging it would buy nothing. An
    optional ``"wtab"`` write table redirects the KV scatter only (the
    mixed token-slot step's shared-prefix recompute path — see the dense
    transformer's decode_step). ``attn_backend`` picks the paged
    self-attention path: ``"gather"`` or the fused ``"pallas"`` kernel
    (layers.paged_attention).
    """
    x = params["tok_embed"][token].astype(jnp.dtype(cfg.dtype))
    paged = "ptab" in cache
    w = (cache["ptab"].shape[1] * cache["kv"]["k"].shape[2] if paged
         else cache["kv"]["k"].shape[2])
    pos = jnp.asarray(pos, jnp.int32)
    pe_table = sinusoid(w, cfg.d_model)
    if pos.ndim:
        pe = pe_table[pos][:, None]                     # (B, 1, d)
    else:
        pe = jax.lax.dynamic_slice_in_dim(pe_table, pos, 1)
    x = x + pe.astype(x.dtype)
    positions = pos if pos.ndim else \
        jnp.full((token.shape[0],), pos, jnp.int32)

    def body(x, lp_kv):
        lp, kv, xkv = lp_kv
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg, rope=False)
        if paged:
            kv = kvcache.write_kv_paged(kv, k, v,
                                        cache.get("wtab", cache["ptab"]),
                                        positions)
            ctx = paged_attention(q, kv["k"], kv["v"], cache["ptab"],
                                  positions, backend=attn_backend)
        else:
            kv = kvcache.write_kv(kv, k, v, pos)
            ctx = attention(q, kv["k"], kv["v"], causal=True, q_offset=pos,
                            kv_len=jnp.minimum(pos + 1, w))
        x = x + attn_out(lp["attn"], ctx, cfg)
        x = _cross(lp, x, xkv, cfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
        return x, kv

    x, kvs = jax.lax.scan(body, x, (params["dec_layers"], cache["kv"],
                                    cache["xkv"]))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    out = {"kv": kvs, "xkv": cache["xkv"], "pos": pos + 1}
    if paged:
        out["ptab"] = cache["ptab"]
    return logits, out
