"""Model zoo. ``get_model(cfg)`` returns the family module implementing the
shared API: init / forward / init_cache / prefill / decode_step."""
from __future__ import annotations

from repro.configs.base import ModelConfig


def get_model(cfg: ModelConfig):
    from repro.models import encdec, hybrid, mamba_lm, transformer, vlm
    return {
        "dense": transformer,
        "moe": transformer,
        "ssm": mamba_lm,
        "hybrid": hybrid,
        "audio": encdec,
        "vlm": vlm,
    }[cfg.arch_type]


def make_batch_shapes(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """Abstract shapes of a training/prefill batch (see launch/specs.py for
    the ShapeDtypeStruct version)."""
    import numpy as np
    shapes = {"tokens": ((batch, seq), np.int32)}
    if cfg.has_encoder:
        shapes["frames"] = ((batch, cfg.encoder_ctx, cfg.d_model),
                            np.float32)
    if cfg.cross_attn_every > 0:
        shapes["image_embeds"] = ((batch, cfg.num_image_tokens, cfg.d_model),
                                  np.float32)
    return shapes
