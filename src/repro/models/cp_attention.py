"""Context-parallel decode attention (beyond-paper optimisation, §Perf).

Problem (measured in the baseline dry-run): for GQA archs whose kv_heads
don't divide the "model" axis (deepseek/qwen/internlm/minitron/kimi/vlm:
kv=8 on a 16-way axis), the decode cache must shard on the SEQUENCE dim.
GSPMD then resolves `dynamic_update_slice` (cache write at `pos`) and the
softmax over the sharded seq by ALL-GATHERING the whole cache in fp32 —
4 gathers + 2 permutes of (B, 32768, 8, 128) PER LAYER PER TOKEN
(~0.38 TB/device/token on deepseek-33b decode_32k).

Fix: express the attention shard-locally with `shard_map`:
  * the owning shard writes the new K/V row (predicated local update);
  * each shard computes partial (m, l, o) online-softmax stats over its
    seq slice;
  * stats combine with one tiny psum/pmax: bytes moved per layer drop from
    O(B·S·Hkv·D) to O(B·Hq·D) — ~5 orders of magnitude at S=32k.

This is the TPU-idiomatic "context parallelism" used by long-context
serving systems; the survey's taxonomy calls it intra-operator parallelism
on the attribute (sequence) dimension.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import pspec as _pspec
from repro.core.compat import shard_map


def cp_available(cache_k) -> bool:
    """CP decode applies when a mesh+rules context is active and the cache
    seq dim divides the model axis."""
    mesh = _pspec._mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    return cache_k.shape[1] % mesh.shape["model"] == 0


def _local_attn_stats(q, k, v, kpos, pos, window, n_rep):
    """Partial online-softmax stats over the local seq slice.
    q (B,1,Hq,D); k/v (B,Sl,Hkv,D); kpos (Sl,). Returns m,l,o (fp32)."""
    b, _, hq, d = q.shape
    # grouped-query einsum: avoids BOTH the repeated-KV materialisation and
    # fp32 copies of the cache (fp32 only in the MXU accumulator).
    g = hq // max(n_rep, 1)                                  # = hkv
    qg = q.reshape(b, 1, g, n_rep, d)
    s = jnp.einsum("bqgrd,btgd->bgrqt", qg, k,
                   preferred_element_type=jnp.float32) / np.sqrt(d)
    mask = (kpos <= pos) & (kpos >= 0)
    if window > 0:
        mask &= kpos > pos - window
    s = jnp.where(mask[None, None, None, None, :], s, -1e30)
    m = s.max(-1)                                            # (B,g,r,1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bgrqt,btgd->bgrqd", p, v,
                   preferred_element_type=jnp.float32)
    bq = 1
    return (m.reshape(b, hq, bq), l.reshape(b, hq, bq),
            o.reshape(b, hq, bq, d))


def cp_decode_attention(q, kv, k_new, v_new, pos, *, window: int = 0,
                        axis: str = "model"):
    """Sharded decode attention + cache write, all shard-local.

    q (B,1,Hq,D) replicated over `axis`; kv {"k","v"} (B,S,Hkv,D) sharded
    on dim 1 over `axis`; k_new/v_new (B,1,Hkv,D) replicated; pos scalar.
    Returns ctx (B,1,Hq,D) and the updated cache dict.
    """
    mesh = _pspec._mesh()
    assert mesh is not None
    n_shard = mesh.shape[axis]
    b, s_total, hkv, d = kv["k"].shape
    hq = q.shape[2]
    n_rep = hq // hkv
    s_local = s_total // n_shard
    # keep the data-parallel batch sharding inside the shard_map specs —
    # otherwise shard_map would all-gather the batch over "data".
    rules = _pspec._rules() or {}
    batch_ax = rules.get("batch")
    if batch_ax is not None:
        dp = 1
        for a in (batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)):
            dp *= mesh.shape[a]
        if b % dp != 0:
            batch_ax = None

    def body(q, k_c, v_c, kn, vn, pos):
        i = jax.lax.axis_index(axis)
        start = i * s_local
        owns = jnp.logical_and(pos >= start, pos < start + s_local)
        li = jnp.clip(pos - start, 0, s_local - 1)
        row_k = jnp.where(owns, kn[:, 0], k_c[:, li])
        row_v = jnp.where(owns, vn[:, 0], v_c[:, li])
        k_c = jax.lax.dynamic_update_index_in_dim(k_c, row_k, li, 1)
        v_c = jax.lax.dynamic_update_index_in_dim(v_c, row_v, li, 1)
        kpos = start + jnp.arange(s_local)
        m, l, o = _local_attn_stats(q, k_c, v_c, kpos, pos, window, n_rep)
        m_g = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis)
        o_g = jax.lax.psum(o * corr[..., None], axis)
        safe = jnp.where(l_g == 0.0, 1.0, l_g)
        ctx = (o_g / safe[..., None]).astype(q.dtype)        # (B,H,1,D)
        return ctx.transpose(0, 2, 1, 3), k_c, v_c

    spec_kv = P(batch_ax, axis, None, None)
    rep4 = P(batch_ax, None, None, None)
    ctx, k2, v2 = shard_map(
        body, mesh=mesh,
        in_specs=(rep4, spec_kv, spec_kv, rep4, rep4, P()),
        out_specs=(rep4, spec_kv, spec_kv),
        check_vma=False,
    )(q, kv["k"], kv["v"], k_new, v_new, pos)
    return ctx, {"k": k2, "v": v2}
