"""Mixture-of-Experts FFN: top-k router + capacity-based dispatch.

Dispatch is GShard/MaxText-style "dropping": each expert has a static
capacity C = ceil(T * k / E * capacity_factor); tokens beyond capacity are
dropped (their residual passes through). All shapes are static, so the block
lowers cleanly under pjit on the production mesh.

Sharding (installed by core/sharding.py):
  * expert-parallel:  experts axis of w_* sharded over the "model" mesh axis;
    the (E, C, d) dispatch buffer is likewise sharded over experts, which
    makes GSPMD emit the all-to-all the paper's MoE case-studies describe.
  * aux load-balance loss (Shazeer-style) returned for the trainer.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pspec import constrain
from repro.models.layers import dense_init


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=0.02),
        "w_gate": dense_init(ks[1], (e, d, f), dt),
        "w_up": dense_init(ks[2], (e, d, f), dt),
        "w_down": dense_init(ks[3], (e, f, d), dt,
                             scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def capacity(num_tokens: int, cfg) -> int:
    c = int(np.ceil(num_tokens * cfg.experts_per_token / cfg.num_experts
                    * cfg.moe_capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly layouts


def router_topk(router_w, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x:(T,d) -> gates (T,k), expert ids (T,k), aux load-balance loss."""
    logits = x.astype(jnp.float32) @ router_w            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    # Shazeer aux loss: E * sum_e fraction_tokens_e * mean_prob_e
    e = cfg.num_experts
    me = probs.mean(0)                                   # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (idx.size))
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def moe_ffn(p, x, cfg, *, act=jax.nn.silu):
    """x: (B, S, d) -> (B, S, d), aux_loss (scalar fp32)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    gates, idx, aux = router_topk(p["router"], xt, cfg)   # (T,k)

    k, e = cfg.experts_per_token, cfg.num_experts
    cap = capacity(t, cfg)

    flat_e = idx.reshape(-1)                              # (T*k,)
    # position of each (token, slot) within its expert, in token order
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)   # (T*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1             # (T*k, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    # scatter-add tokens into the (E*C, d) dispatch buffer. The indices only
    # touch dim 0, so constraining d over "model" lets GSPMD partition the
    # scatter instead of replicating the whole buffer on every device.
    dest = jnp.where(keep, flat_e * cap + pos, 0)
    src = jnp.repeat(xt, k, axis=0)                       # (T*k, d)
    src = jnp.where(keep[:, None], src, 0)                # dropped -> +0
    src = constrain(src, None, "moe_dispatch_d")
    buf = jnp.zeros((e * cap, d), xt.dtype).at[dest].add(src)
    buf = constrain(buf, None, "moe_dispatch_d")
    buf = buf.reshape(e, cap, d)
    buf = constrain(buf, "experts", "moe_cap", None)

    # expert computation: batched over the (sharded) expert axis
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = act(g) * h
    h = constrain(h, "experts", "moe_cap", None)
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y_e = constrain(y_e, "experts", "moe_cap", None)

    # combine: gather each kept slot back and weight by its gate.
    # Same trick: gather indexes dim 0 only -> keep d sharded over "model".
    flat_gate = jnp.where(keep, gates.reshape(-1), 0.0)
    y = constrain(y_e.reshape(e * cap, d), None, "moe_dispatch_d")
    gathered = jnp.where(keep[:, None], y[dest], 0)
    gathered = constrain(gathered, None, "moe_dispatch_d")
    out = (gathered * flat_gate[:, None].astype(gathered.dtype)
           ).reshape(t, k, d).sum(axis=1)
    return out.reshape(b, s, d), aux
