"""Mamba2 block — SSD (state-space duality) with the chunked algorithm
[arXiv:2405.21060], plus the O(1)-state recurrent step for decode.

Layout: H SSD heads of P channels (din = H*P = expand*d_model), single
B/C group (G=1, as in the released Mamba2 models), state size N per head.

TP adaptation of the paper's Megatron idea for an attention-free block
(DESIGN.md §4.1): in_proj is column-split so each device owns whole heads
(the chunked scan is then fully local); out_proj is row-split => exactly one
all-reduce per block, the same collective count as the Megatron MLP.

train/prefill: chunked SSD — intra-chunk (Q x Q) masked-decay attention-dual
+ inter-chunk state scan (lax.scan).  decode: h <- a*h + dt*B(x)x, y = C.h.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pspec import constrain
from repro.models.layers import dense_init, rmsnorm


def _din(cfg) -> int:
    return cfg.ssm_heads * cfg.ssm_head_dim


def conv_channels(cfg) -> int:
    return _din(cfg) + 2 * cfg.ssm_state


def init_mamba(key, cfg):
    d, h, n, w = cfg.d_model, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_width
    din = _din(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        # order: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * n + h), dt),
        "conv_w": dense_init(ks[1], (w, conv_channels(cfg)), jnp.float32, 0.5),
        "conv_b": jnp.zeros((conv_channels(cfg),), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, h, dtype=jnp.float32))),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "gn_scale": jnp.ones((din,), jnp.float32),
        "out_proj": dense_init(ks[2], (din, d), dt,
                               scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }


def _split_in(p, x, cfg):
    """in_proj + split. x:(B,S,d) -> z, xbc:(B,S,din+2N), dt:(B,S,H)."""
    din, n, h = _din(cfg), cfg.ssm_state, cfg.ssm_heads
    zxbcdt = x @ p["in_proj"]
    zxbcdt = constrain(zxbcdt, "batch", None, "ssm_inner")
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * n]
    dt_raw = zxbcdt[..., 2 * din + 2 * n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, *, init_state=None):
    """Depthwise causal conv (width W) over (B,S,C). Returns y, final tail."""
    w = conv_w.shape[0]
    x32 = xbc.astype(jnp.float32)
    if init_state is None:
        pad = jnp.zeros((x32.shape[0], w - 1, x32.shape[2]), jnp.float32)
    else:
        pad = init_state.astype(jnp.float32)
    xp = jnp.concatenate([pad, x32], axis=1)
    y = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(w)) + conv_b
    tail = xp[:, -(w - 1):] if w > 1 else xp[:, :0]
    return jax.nn.silu(y).astype(xbc.dtype), tail.astype(xbc.dtype)


def ssd_chunked(xh, dt, A, Bm, Cm, *, chunk: int):
    """Chunked SSD.  xh:(B,S,H,P) dt:(B,S,H) A:(H,) Bm,Cm:(B,S,N).

    Returns y:(B,S,H,P) and final state (B,H,P,N).
    """
    b, s, h, p_ = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    dtA = dt * A[None, None, :]                        # (B,S,H)  (A negative)
    x_dt = xh * dt[..., None]                          # absorb dt into x
    # chunked views
    la = dtA.reshape(b, nc, q, h)
    cum = jnp.cumsum(la, axis=2)                       # (B,nc,Q,H) log-decay to t
    xc = x_dt.reshape(b, nc, q, h, p_)
    bc = Bm.reshape(b, nc, q, n)
    cc = Cm.reshape(b, nc, q, n)

    # ---- intra-chunk (the "attention dual"):
    # att[b,c,h,i,j] = (C_i . B_j) * exp(cum_i - cum_j) for i >= j
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc,
                        preferred_element_type=jnp.float32)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    att = scores[..., None] * jnp.exp(dec)                   # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att,
                         xc.astype(jnp.float32))

    # ---- chunk states: S_c[h,p,n] = sum_j exp(cum_last - cum_j) B_j x_j
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc.astype(jnp.float32),
                        dec_end, xc.astype(jnp.float32))

    # ---- inter-chunk scan
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    def step(hprev, inp):
        st, cd = inp                                          # (B,H,P,N),(B,H)
        return cd[:, :, None, None] * hprev + st, hprev

    h0 = jnp.zeros((b, h, p_, n), jnp.float32)
    hlast, hprevs = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    hprevs = hprevs.swapaxes(0, 1)                           # (B,nc,H,P,N)

    # y_inter[i] = exp(cum_i) * C_i . h_prev(chunk)
    y_inter = jnp.einsum("bcin,bchpn->bcihp", cc.astype(jnp.float32), hprevs)
    y_inter = y_inter * jnp.exp(cum)[..., None]              # (B,nc,Q,H,1)
    y = (y_intra + y_inter).reshape(b, s, h, p_)
    return y.astype(xh.dtype), hlast


def mamba_forward(p, x, cfg, *, return_state: bool = False):
    """Full-sequence Mamba2 block. x:(B,S,d) -> (B,S,d) [, cache]."""
    din, n, h = _din(cfg), cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = _split_in(p, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xh = xbc[..., :din].reshape(*x.shape[:2], h, cfg.ssm_head_dim)
    Bm = xbc[..., din:din + n]
    Cm = xbc[..., din + n:]
    A = -jnp.exp(p["A_log"])
    xh = constrain(xh, "batch", None, "ssm_heads", None)
    y, hlast = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    y = (y.astype(jnp.float32)
         + xh.astype(jnp.float32) * p["D"][None, None, :, None])
    y = y.reshape(*x.shape[:2], din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gn_scale"], cfg.norm_eps)
    out = y @ p["out_proj"]
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, {"state": hlast, "conv": conv_tail}
    return out


def mamba_step(p, cache, x_t, cfg):
    """One decode token. x_t:(B,1,d), cache {state:(B,H,P,N), conv:(B,W-1,C)}."""
    din, n, h = _din(cfg), cfg.ssm_state, cfg.ssm_heads
    z, xbc, dt = _split_in(p, x_t, cfg)                   # (B,1,*)
    w = cfg.ssm_conv_width
    hist = jnp.concatenate([cache["conv"].astype(jnp.float32),
                            xbc.astype(jnp.float32)], axis=1)  # (B,W,C)
    y = (hist * p["conv_w"][None]).sum(1, keepdims=True) + p["conv_b"]
    xbc_c = jax.nn.silu(y).astype(xbc.dtype)
    new_conv = hist[:, 1:].astype(xbc.dtype)

    xh = xbc_c[..., :din].reshape(-1, h, cfg.ssm_head_dim)     # (B,H,P)
    Bm = xbc_c[:, 0, din:din + n]                              # (B,N)
    Cm = xbc_c[:, 0, din + n:]
    A = -jnp.exp(p["A_log"])
    dt0 = dt[:, 0]                                             # (B,H)
    a = jnp.exp(dt0 * A[None])                                 # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt0, Bm.astype(jnp.float32),
                     xh.astype(jnp.float32))
    state = a[:, :, None, None] * cache["state"] + upd
    y_t = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), state)
    y_t = y_t + xh.astype(jnp.float32) * p["D"][None, :, None]
    y_t = y_t.reshape(x_t.shape[0], 1, din)
    y_t = rmsnorm(y_t.astype(x_t.dtype) *
                  jax.nn.silu(z.astype(jnp.float32)).astype(x_t.dtype),
                  p["gn_scale"], cfg.norm_eps)
    out = y_t @ p["out_proj"]
    return out, {"state": state, "conv": new_conv}


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    h, p_, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, h, p_, n), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_channels(cfg)),
                          dtype),
    }
