"""Zamba2-style hybrid: Mamba2 backbone + a SHARED full-attention block
applied every ``hybrid_attn_every`` mamba layers [arXiv:2411.15242].

The shared block's WEIGHTS are shared across its applications; each
application keeps its own KV cache. Structure:

    G = num_layers // hybrid_attn_every groups of
        [every x (norm -> mamba)] -> shared (norm -> attn -> norm -> mlp)
    + (num_layers % every) trailing mamba layers.

At long_500k the shared attention runs with a sliding window (ring cache)
so total decode state stays O(G * (window + ssm_state)) — sub-quadratic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pspec import constrain
from repro.models import kvcache, ssm
from repro.models.layers import (attention, attn_out, attn_qkv, dense_init,
                                 init_attn, init_mlp, mlp, rmsnorm)
from repro.models.mamba_lm import init_layer as init_mamba_layer
from repro.models.transformer import cache_window


def _gl(cfg):
    g = cfg.num_layers // cfg.hybrid_attn_every
    rest = cfg.num_layers - g * cfg.hybrid_attn_every
    return g, rest


def init(key, cfg):
    ke, kg, kr, ks_, kh = jax.random.split(key, 5)
    g, rest = _gl(cfg)
    grouped = jax.vmap(jax.vmap(lambda k: init_mamba_layer(k, cfg)))(
        jax.random.split(kg, (g, cfg.hybrid_attn_every)))
    trailing = jax.vmap(lambda k: init_mamba_layer(k, cfg))(
        jax.random.split(kr, max(rest, 1)))
    ka, km = jax.random.split(ks_)
    shared = {"attn": init_attn(ka, cfg),
              "mlp": init_mlp(km, cfg),
              "ln1": jnp.ones((cfg.d_model,), jnp.float32),
              "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model),
                            jnp.dtype(cfg.dtype)),
        "groups": grouped,           # (G, every, ...)
        "trailing": trailing,        # (rest or 1, ...)
        "shared": shared,            # single shared attn+mlp block
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size),
                              jnp.dtype(cfg.dtype)),
    }


def _mamba_sub(x, lp, cfg):
    return x + ssm.mamba_forward(lp["mamba"],
                                 rmsnorm(x, lp["norm"], cfg.norm_eps), cfg)


def _shared_block(sp, x, cfg, *, attn_impl="auto"):
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    q, k, v = attn_qkv(sp["attn"], h, cfg)
    ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                    impl=attn_impl)
    x = x + attn_out(sp["attn"], ctx, cfg)
    h = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + mlp(sp["mlp"], h)


def _head(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["lm_head"], "batch", None, "vocab")


def forward(params, batch, cfg, *, remat: bool = False, attn_impl="auto"):
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    g, rest = _gl(cfg)
    sp = params["shared"]

    def group(x, glp):
        def inner(x, lp):
            return _mamba_sub(x, lp, cfg), None
        x, _ = jax.lax.scan(inner, x, glp)
        return _shared_block(sp, x, cfg, attn_impl=attn_impl), None

    if remat:
        group = jax.checkpoint(group, prevent_cse=False)
    x, _ = jax.lax.scan(group, x, params["groups"])
    if rest:
        def inner(x, lp):
            return _mamba_sub(x, lp, cfg), None
        x, _ = jax.lax.scan(inner, x, params["trailing"])
    return _head(params, x, cfg), jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    g, rest = _gl(cfg)
    w = cache_window(cfg, max_len)
    one = ssm.init_mamba_cache(cfg, batch, dtype)
    kv = kvcache.init_kv(batch, w, cfg.num_kv_heads, cfg.head_dim, dtype)
    stack = lambda t, n: jax.tree.map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), t)
    return {"ssm_g": stack(one, g * cfg.hybrid_attn_every),
            "ssm_t": stack(one, max(rest, 1)),
            "kv": stack(kv, g),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, cache, *, attn_impl="auto"):
    tokens = batch["tokens"]
    s = tokens.shape[1]
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
    g, rest = _gl(cfg)
    sp = params["shared"]
    w = cache["kv"]["k"].shape[2]

    def group(x, glp):
        def inner(x, lp):
            y, st = ssm.mamba_forward(
                lp["mamba"], rmsnorm(x, lp["norm"], cfg.norm_eps), cfg,
                return_state=True)
            return x + y, st
        x, sts = jax.lax.scan(inner, x, glp)
        h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(sp["attn"], h, cfg)
        ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                        impl=attn_impl)
        x = x + attn_out(sp["attn"], ctx, cfg)
        x = x + mlp(sp["mlp"], rmsnorm(x, sp["ln2"], cfg.norm_eps))
        return x, (sts, {"k": kvcache.fit_prefill(k, w), "v": kvcache.fit_prefill(v, w)})

    x, (ssm_states, kvs) = jax.lax.scan(group, x, params["groups"])
    # ssm_states: (G, every, ...) -> flatten to (G*every, ...)
    ssm_g = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), ssm_states)
    if rest:
        def inner(x, lp):
            y, st = ssm.mamba_forward(
                lp["mamba"], rmsnorm(x, lp["norm"], cfg.norm_eps), cfg,
                return_state=True)
            return x + y, st
        x, ssm_t = jax.lax.scan(inner, x, params["trailing"])
    else:
        ssm_t = jax.tree.map(lambda a: a[None] * 0,
                             ssm.init_mamba_cache(cfg, tokens.shape[0],
                                                  x.dtype))
    cache = {"ssm_g": ssm_g, "ssm_t": ssm_t, "kv": kvs,
             "pos": jnp.asarray(s, jnp.int32)}
    return _head(params, x[:, -1:], cfg), cache


def decode_step(params, cache, token, pos, cfg):
    """``pos``: scalar (lockstep) or (B,) per-row vector (slot-table)."""
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    g, rest = _gl(cfg)
    sp = params["shared"]
    w = cache["kv"]["k"].shape[2]
    ring = cfg.sliding_window > 0 and w == cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim else \
        jnp.full((token.shape[0], 1), pos)
    e = cfg.hybrid_attn_every
    ssm_g = jax.tree.map(lambda a: a.reshape((g, e) + a.shape[1:]),
                         cache["ssm_g"])

    def group(x, inp):
        glp, sts, kv = inp

        def inner(x_st, lp_st):
            x, _ = x_st
            lp, st = lp_st
            y, st = ssm.mamba_step(lp["mamba"],
                                   st, rmsnorm(x, lp["norm"], cfg.norm_eps),
                                   cfg)
            return (x + y, None), st

        (x, _), sts = jax.lax.scan(inner, (x, None), (glp, sts))
        h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(sp["attn"], h, cfg, positions=positions)
        kv = kvcache.write_kv(kv, k, v, pos, ring=ring, window=w)
        kpos = kvcache.ring_kpos(positions, w) if ring else None
        kv_len = None if ring else jnp.minimum(pos + 1, w)
        ctx = attention(q, kv["k"], kv["v"], causal=True,
                        window=cfg.sliding_window, q_offset=pos,
                        kv_len=kv_len, kpos=kpos)
        x = x + attn_out(sp["attn"], ctx, cfg)
        x = x + mlp(sp["mlp"], rmsnorm(x, sp["ln2"], cfg.norm_eps))
        return x, (sts, kv)

    x, (ssm_g, kvs) = jax.lax.scan(group, x, (params["groups"], ssm_g,
                                              cache["kv"]))
    ssm_g = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), ssm_g)
    ssm_t = cache["ssm_t"]
    if rest:
        def inner(x_st, lp_st):
            x, _ = x_st
            lp, st = lp_st
            y, st = ssm.mamba_step(lp["mamba"],
                                   st, rmsnorm(x, lp["norm"], cfg.norm_eps),
                                   cfg)
            return (x + y, None), st
        (x, _), ssm_t = jax.lax.scan(inner, (x, None),
                                     (params["trailing"], cache["ssm_t"]))
    new = {"ssm_g": ssm_g, "ssm_t": ssm_t, "kv": kvs, "pos": pos + 1}
    return _head(params, x, cfg), new
