"""Core layers: norms, RoPE, GQA attention (full / chunked / sliding-window),
gated + plain MLPs.

Attention has three execution paths:
  * "full":     materialise (B,H,S,T) scores — small shapes / tests only.
  * "chunked":  double-blocked online-softmax (flash-style in pure XLA) —
                the default HLO path for big shapes; memory O(S*Ck) not O(S^2).
  * Pallas:     kernels/flash_attention.py — the TPU target, selected by
                ops-level dispatch, validated vs ref in interpret mode.

``window > 0`` gives sliding-window attention: each query attends to the
previous ``window`` positions only; the chunked path then visits a STATIC
number of KV chunks per query chunk => sub-quadratic compute (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pspec import constrain
from repro.models.kvcache import gather_pages

# ---------------------------------------------------------------- init utils

def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# --------------------------------------------------------------------- norms

def rmsnorm(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv * scale).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ---------------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)      # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d)
                            ).reshape(b, t, h * n_rep, d)


def attention_full(q, k, v, *, causal: bool, window: int = 0,
                   q_offset: int = 0, kv_len: Optional[jnp.ndarray] = None,
                   kpos: Optional[jnp.ndarray] = None):
    """Reference/small-shape path. q:(B,S,Hq,D) k,v:(B,T,Hkv,D) -> (B,S,Hq,D).

    q_offset: absolute position of q[0] (decode: q_offset = pos). Scalar, or
              (B,) for the slot-table decode where every row sits at its own
              depth.
    kv_len: optional dynamic valid length of the KV (decode cache fill
            level); scalar or per-row (B,).
    kpos:   optional absolute position per KV slot (ring caches); (T,) or
            per-row (B, T); entries < 0 are masked out.
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    k, v = _repeat_kv(k, hq // hkv), _repeat_kv(v, hq // hkv)
    scores = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32)
    scores *= 1.0 / np.sqrt(d)
    # per-row broadcasting: qpos (1|B, S), kpos (1|B, T) -> mask (1|B, S, T)
    qpos = jnp.asarray(q_offset).reshape(-1, 1) + jnp.arange(s)
    if kpos is None:
        kpos = jnp.arange(t)
    kpos = jnp.asarray(kpos)
    kpos = kpos[None, :] if kpos.ndim == 1 else kpos
    mask = jnp.ones((1, s, t), bool)
    if causal:
        mask = mask & (kpos[:, None, :] <= qpos[:, :, None])
    if window > 0:
        mask &= kpos[:, None, :] > qpos[:, :, None] - window
    if kv_len is not None:
        mask &= kpos[:, None, :] < jnp.asarray(kv_len).reshape(-1, 1, 1)
    mask &= kpos[:, None, :] >= 0
    scores = jnp.where(mask[:, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    backend: str = "gather"):
    """Decode-time block-table attention over a paged KV pool (vLLM-style).

    q: (B, 1, Hq, D) — one fresh token per slot-table row.
    k_pages/v_pages: (n_pages, page_size, Hkv, D) — the flat shared pool.
    page_table: (B, P) pool indices per row (0 = null page).
    pos: (B,) per-row cursors (tokens already in context, incl. this one's
    write — the query attends to positions [0, pos]).

    Two backends, token-identical greedy outputs:

    ``backend="gather"`` (default) gathers each row's pages in logical-
    block order, so the gathered axis IS the position axis and the dense
    mask machinery applies unchanged: ``kv_len = pos + 1`` hides
    null/garbage tail pages. The gather is a table lookup — table VALUES
    change between steps, shapes never do, so the batched decode program
    still traces exactly once. It materializes ``P * page_size``
    positions per row per layer, where ``P`` is the WIDTH OF THE TABLE
    PASSED IN — the serve engine hands this function a table clipped to
    the power-of-two bucket of the allocator's per-slot page high-water
    mark (serve/step.page_bucket), so decode cost tracks pool occupancy
    rather than ``max_len`` and the program only retraces when the
    high-water crosses a bucket boundary.

    ``backend="pallas"`` runs the fused flash-decoding kernel
    (kernels/paged_attention.py): one grid block per page with online
    softmax carried across the page axis, the pool indexed through the
    scalar-prefetched table — contiguous KV is never materialized and
    GQA heads fold in-kernel. Same masking (``kv_len = pos + 1``), same
    trace cadence (shapes depend only on the bucketed table width); on
    CPU it runs in interpret mode (kernels/ops.INTERPRET).

    TP note: under a ("data", "model") mesh the pool is head-sharded
    over "model" (core/sharding.cache_pspecs) — both backends index the
    unsharded page axis and stay head-local per device (each sees its
    own Hkv/tp heads) until the row-sharded output projection's
    all-reduce.
    """
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.paged_attention(q, k_pages, v_pages, page_table, pos)
    if backend != "gather":
        raise ValueError(
            f"paged_attention backend must be 'gather' or 'pallas', "
            f"got {backend!r}")
    kv_len = jnp.asarray(pos) + 1
    k = gather_pages(k_pages, page_table)
    v = gather_pages(v_pages, page_table)
    return attention_full(q, k, v, causal=True, q_offset=pos, kv_len=kv_len)


def _attn_block(q, k, v, qpos, kpos, scale, causal, window, m, l, acc):
    """One (q-chunk, kv-chunk) online-softmax update. fp32 carries."""
    s = jnp.einsum("bshd,bthd->bhst", q, k,
                   preferred_element_type=jnp.float32) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(-1))                    # (B,H,Sq)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhst,bthd->bhsd", p, v.astype(jnp.float32))
    return m_new, l_new, acc_new


def attention_chunked(q, k, v, *, causal: bool, window: int = 0,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      impl: str = "masked"):
    """Double-blocked flash-style attention in pure XLA.

    impl="masked":   every (qi, ki) block pair is computed, causally-dead
                     blocks masked out (paper-faithful naive baseline; HLO
                     FLOPs ~2x the causal ideal).
    impl="triangle": only lower-triangle block pairs are computed (static
                     pair list) — the beyond-paper compute optimisation.
    For window>0 each q chunk visits a STATIC slice of the KV of length
    window+q_chunk => sub-quadratic.
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    k, v = _repeat_kv(k, hq // hkv), _repeat_kv(v, hq // hkv)
    q_chunk, kv_chunk = min(q_chunk, s), min(kv_chunk, t)
    assert s % q_chunk == 0 and t % kv_chunk == 0
    nq, nk = s // q_chunk, t // kv_chunk
    scale = 1.0 / np.sqrt(d)

    qs = q.reshape(b, nq, q_chunk, hq, d)

    if window > 0:
        # static KV window per q chunk: [start, start + wlen)
        wlen = min(t, ((window + q_chunk + kv_chunk - 1) // kv_chunk) * kv_chunk)

        def one_q(qi, qc):
            start = jnp.clip(qi * q_chunk + q_chunk - wlen, 0, t - wlen)
            kc = jax.lax.dynamic_slice_in_dim(k, start, wlen, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, wlen, axis=1)
            qpos = jnp.arange(q_chunk) + qi * q_chunk
            kpos = jnp.arange(wlen) + start
            m = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
            l = jnp.zeros((b, hq, q_chunk), jnp.float32)
            acc = jnp.zeros((b, hq, q_chunk, d), jnp.float32)
            m, l, acc = _attn_block(qc, kc, vc, qpos, kpos, scale,
                                    causal, window, m, l, acc)
            return (acc / l[..., None]).astype(q.dtype)

        out = jax.lax.map(lambda args: one_q(*args),
                          (jnp.arange(nq), qs.swapaxes(0, 1)))
        return out.transpose(1, 0, 3, 2, 4).reshape(b, s, hq, d)

    ks = k.reshape(b, nk, kv_chunk, hq, d)
    vs = v.reshape(b, nk, kv_chunk, hq, d)

    if impl == "triangle" and causal and nq == nk:
        # static lower-triangle pair list, grouped by q chunk
        def one_q(qi, qc):
            qpos = jnp.arange(q_chunk) + qi * q_chunk
            m = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
            l = jnp.zeros((b, hq, q_chunk), jnp.float32)
            acc = jnp.zeros((b, hq, q_chunk, d), jnp.float32)

            def body(ki, carry):
                m, l, acc = carry
                kc = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, 1)
                vc = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, 1)
                kpos = jnp.arange(kv_chunk) + ki * kv_chunk
                return _attn_block(qc, kc, vc, qpos, kpos, scale,
                                   causal, window, m, l, acc)

            m, l, acc = jax.lax.fori_loop(0, qi + 1, body, (m, l, acc))
            return (acc / l[..., None]).astype(q.dtype)

        out = jax.lax.map(lambda args: one_q(*args),
                          (jnp.arange(nq), qs.swapaxes(0, 1)))
        return out.transpose(1, 0, 3, 2, 4).reshape(b, s, hq, d)

    # masked baseline: all nq*nk block pairs
    def one_q(qi, qc):
        qpos = jnp.arange(q_chunk) + qi * q_chunk
        m = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, hq, q_chunk), jnp.float32)
        acc = jnp.zeros((b, hq, q_chunk, d), jnp.float32)

        def body(carry, kvi):
            m, l, acc = carry
            kc, vc, ki = kvi
            kpos = jnp.arange(kv_chunk) + ki * kv_chunk
            m, l, acc = _attn_block(qc, kc, vc, qpos, kpos, scale,
                                    causal, window, m, l, acc)
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(
            body, (m, l, acc),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))
        safe_l = jnp.where(l == 0, 1.0, l)
        return (acc / safe_l[..., None]).astype(q.dtype)

    out = jax.lax.map(lambda args: one_q(*args),
                      (jnp.arange(nq), qs.swapaxes(0, 1)))
    return out.transpose(1, 0, 3, 2, 4).reshape(b, s, hq, d)


def attention(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
              kv_len=None, kpos=None, impl: str = "auto", q_chunk: int = 1024,
              kv_chunk: int = 1024):
    """Dispatching entry point used by all models."""
    s, t = q.shape[1], k.shape[1]
    if impl == "triangle" and (not causal or s != t or s % q_chunk):
        impl = "auto"            # triangle needs a square causal grid
    if impl == "auto":
        impl = "full" if (s * t <= 2048 * 2048 or s == 1) else "chunked"
    if impl == "full" or s == 1 or kv_len is not None or kpos is not None:
        return attention_full(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, kv_len=kv_len, kpos=kpos)
    if impl in ("chunked", "masked"):
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 impl="masked")
    if impl == "triangle":
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 q_chunk=q_chunk, kv_chunk=kv_chunk,
                                 impl="triangle")
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    raise ValueError(impl)


# ------------------------------------------------------- attention (module)

def init_attn(key, cfg, *, cross: bool = False):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, qd), jnp.dtype(cfg.dtype)),
        "wk": dense_init(ks[1], (d, kvd), jnp.dtype(cfg.dtype)),
        "wv": dense_init(ks[2], (d, kvd), jnp.dtype(cfg.dtype)),
        "wo": dense_init(ks[3], (qd, d), jnp.dtype(cfg.dtype),
                         scale=0.02 / np.sqrt(2 * cfg.num_layers)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    return p


def attn_qkv(p, x, cfg, *, positions=None, rope: bool = True):
    """Project to q,k,v (+qk_norm, +rope). x:(B,S,d) -> q(B,S,Hq,D), k/v."""
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        if positions is None:
            positions = jnp.arange(s)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


def attn_out(p, ctx, cfg):
    b, s = ctx.shape[:2]
    out = ctx.reshape(b, s, cfg.q_dim) @ p["wo"]
    return constrain(out, "batch", None, None)


# ----------------------------------------------------------------------- MLP

def init_mlp(key, cfg, *, d_ff: Optional[int] = None, gated: bool = True):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], (d, f), dt),
         "w_down": dense_init(ks[2], (f, d), dt,
                              scale=0.02 / np.sqrt(2 * cfg.num_layers))}
    if gated:
        p["w_gate"] = dense_init(ks[0], (d, f), dt)
    return p


def mlp(p, x, *, act=jax.nn.silu):
    """Gated (SwiGLU) if w_gate present else plain-GeLU MLP.

    This is the paper's §5.1 MLP: column-parallel first matmul(s) keep the
    nonlinearity local; row-parallel second matmul needs one all-reduce
    (generated by GSPMD from the shardings).
    """
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = act(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "batch", None, "d_ff")
    return constrain(h @ p["w_down"], "batch", None, None)
