"""KV-cache utilities shared by all attention archs.

Two layouts:
  * linear cache: (B, Smax, Hkv, D) with write at ``pos`` — train-free decode
    up to Smax (decode_32k).
  * ring cache (sliding-window archs at long_500k): (B, W, Hkv, D); slot
    ``pos % W``; the slot->absolute-position map is recomputed analytically,
    so memory is O(W) not O(S) — the sub-quadratic carve-in of DESIGN.md §4.

Batched slot-table layout (the serve engine)
--------------------------------------------
The batch dim doubles as the SLOT TABLE of the continuous-batching engine:
row ``b`` of every cache leaf is the private context of one in-flight
request, and requests sit at *different* depths. ``pos`` is therefore
allowed to be a per-row **vector** (B,) everywhere below, not just a
scalar:

  * ``write_kv`` scatters row-wise — ``k[arange(B), pos[b]] = k_new[b]`` —
    so one device program writes every slot's next token at its own cursor;
  * ``ring_kpos`` broadcasts over a (B, 1) position to give the per-row
    slot->absolute map (B, W);
  * the attention mask (layers.attention_full) takes per-row
    ``q_offset``/``kv_len``/``kpos`` so each row attends exactly to its own
    valid prefix.

A model-level ``cache["pos"]`` stays a scalar for the lockstep paths
(greedy_generate, dry-runs); the engine keeps its own (slots,) vector and
passes it to ``decode_step`` directly.

Paged layout (vLLM-style block tables; arXiv:2309.06180)
--------------------------------------------------------
The third layout drops per-slot rows entirely: one flat POOL of
fixed-size pages ``(layers, n_pages, page_size, Hkv, D)`` shared by every
slot, plus a per-slot page table ``ptab`` (slots, P) of pool indices that
maps logical block ``p // page_size`` of slot ``b`` to a physical page.
Token ``p`` of slot ``b`` therefore lives at
``pool[ptab[b, p // page_size], p % page_size]``:

  * ``write_kv_paged`` scatters each row's decode token through the table
    at its own cursor — still ONE device program for the whole slot table;
  * ``layers.paged_attention`` gathers ``pool[ptab[b]]`` so the gathered
    axis IS the position axis, then masks to each row's live prefix —
    identical math to the dense path, so paged and dense decode are
    token-identical for row-independent (non-MoE) archs; MoE capacity
    routing couples slot rows either way, and the layouts feed inactive
    rows different scratch, so batched MoE keeps its existing
    occupancy-dependence caveat across layouts;
  * page id 0 is the NULL page: inactive slots and bucket padding write
    there harmlessly, and table entries beyond a slot's reservation point
    at it (always masked by ``kv_len``).

WHICH pages a slot owns is host-side bookkeeping
(``serve/paging.PageAllocator``); the device never sees the free-list,
only the table values, so admission/churn never retraces the step.

Prefix sharing (serve/prefix.py) rides on the same property: a page may
appear in SEVERAL slots' table rows (a common prompt prefix held once),
and only table values change, so decode still traces exactly once. The
one device-side addition is ``copy_page`` — the copy-on-write step that
duplicates a shared page's contents before a writer appends into it.

Sharded (TP) pool layout: under a ("data", "model") mesh the pool keeps
this exact shape but is partitioned on the KV-HEAD axis —
``(L, n_pages, page_size, Hkv/tp, D)`` per device
(core/sharding.cache_pspecs) — so every device holds its head slice of
EVERY page and each resident page costs 1/tp per device. Page ids stay
global (the page/table axes are never sharded: a table lookup must
resolve on every device), which is why the whole serve bookkeeping —
allocator, prefix cache, preemption — is sharding-blind: it only ever
deals in page ids and table values.
"""
from __future__ import annotations

import jax.numpy as jnp


def init_kv(batch: int, length: int, n_kv: int, head_dim: int, dtype):
    shape = (batch, length, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_paged_kv(layers: int, n_pages: int, page_size: int, n_kv: int,
                  head_dim: int, dtype):
    """Flat page pool shared by every slot. ``n_pages`` INCLUDES the null
    page 0 (so a pool serving K usable pages has n_pages = K + 1)."""
    shape = (layers, n_pages, page_size, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def write_kv_paged(cache, k_new, v_new, page_table, pos):
    """Scatter one decode token per slot through the page table.

    cache leaves: (n_pages, page_size, Hkv, D) — ONE layer's pool (models
    scan over the stacked layer axis). k_new/v_new: (B, 1, Hkv, D);
    page_table: (B, P) pool indices; pos: (B,) per-row cursors. Inactive
    slots resolve to the null page 0 (their table rows are zeroed and the
    block index is clipped), so the scatter is total — no masking branch,
    no retrace.
    """
    page_size = cache["k"].shape[1]
    pos = jnp.asarray(pos)
    rows = jnp.arange(page_table.shape[0])
    blk = jnp.clip(pos // page_size, 0, page_table.shape[1] - 1)
    page = page_table[rows, blk]
    off = pos % page_size
    cache = dict(cache)
    cache["k"] = cache["k"].at[page, off].set(k_new[:, 0])
    cache["v"] = cache["v"].at[page, off].set(v_new[:, 0])
    return cache


def copy_page(pool, src, dst):
    """Copy one physical page's contents, all layers at once — the device
    half of copy-on-write (the allocator swaps the table entry, this moves
    the KV). pool leaves: (L, n_pages, page_size, Hkv, D); src/dst:
    scalar page ids (traced values, so ONE program covers every copy)."""
    return {"k": pool["k"].at[:, dst].set(pool["k"][:, src]),
            "v": pool["v"].at[:, dst].set(pool["v"][:, src])}


def gather_pages(pool, page_table):
    """pool: (n_pages, page_size, ...); page_table: (B, P) -> contiguous
    per-row KV (B, P * page_size, ...) in logical position order."""
    b, p = page_table.shape
    out = pool[page_table]
    return out.reshape(b, p * pool.shape[1], *pool.shape[2:])


def ring_slot(pos, window: int):
    return pos % window


def ring_kpos(pos, window: int):
    """Absolute position held by each ring slot at time ``pos`` (may be <0
    for not-yet-filled slots; the attention mask drops those).

    ``pos`` scalar -> (W,); ``pos`` (B, 1) -> (B, W) per-row maps."""
    i = jnp.arange(window)
    return pos - ((pos - i) % window)


def fit_prefill(k, w: int):
    """Fit freshly-computed prefill K or V (B,S,Hkv,D) into a cache of
    length ``w``.  S >= w: keep the last w, rolled so absolute position
    ``p`` lands in ring slot ``p % w`` (the invariant ``ring_kpos``
    assumes — a no-op when S % w == 0, but required for arbitrary prompt
    lengths).  S < w: place at the front and zero-pad the tail (linear
    layout, also ring-consistent since p < w)."""
    s = k.shape[1]
    if s >= w:
        return jnp.roll(k[:, -w:], s % w, axis=1)
    return jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))


def write_kv(cache, k_new, v_new, pos, *, ring: bool = False, window: int = 0):
    """k_new/v_new: (B, 1, Hkv, D); pos: scalar int32 (lockstep write at one
    cursor) or (B,) int32 (per-row cursors — the slot-table scatter)."""
    pos = jnp.asarray(pos)
    idx = ring_slot(pos, window) if ring else pos
    cache = dict(cache)
    if idx.ndim == 0:
        cache["k"] = cache["k"].at[:, idx].set(k_new[:, 0])
        cache["v"] = cache["v"].at[:, idx].set(v_new[:, 0])
    else:
        rows = jnp.arange(cache["k"].shape[0])
        cache["k"] = cache["k"].at[rows, idx].set(k_new[:, 0])
        cache["v"] = cache["v"].at[rows, idx].set(v_new[:, 0])
    return cache
