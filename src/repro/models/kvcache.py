"""KV-cache utilities shared by all attention archs.

Two layouts:
  * linear cache: (B, Smax, Hkv, D) with write at ``pos`` — train-free decode
    up to Smax (decode_32k).
  * ring cache (sliding-window archs at long_500k): (B, W, Hkv, D); slot
    ``pos % W``; the slot->absolute-position map is recomputed analytically,
    so memory is O(W) not O(S) — the sub-quadratic carve-in of DESIGN.md §4.
"""
from __future__ import annotations

import jax.numpy as jnp


def init_kv(batch: int, length: int, n_kv: int, head_dim: int, dtype):
    shape = (batch, length, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def ring_slot(pos, window: int):
    return pos % window


def ring_kpos(pos, window: int):
    """Absolute position held by each ring slot at time ``pos`` (may be <0
    for not-yet-filled slots; the attention mask drops those)."""
    i = jnp.arange(window)
    return pos - ((pos - i) % window)


def fit_prefill(k, w: int):
    """Fit freshly-computed prefill K or V (B,S,Hkv,D) into a cache of
    length ``w``.  S >= w: keep the last w (ring layout is consistent when
    S % w == 0, which holds for all assigned shapes).  S < w: place at the
    front and zero-pad the tail (linear layout)."""
    s = k.shape[1]
    if s >= w:
        return k[:, -w:]
    return jnp.pad(k, ((0, 0), (0, w - s), (0, 0), (0, 0)))


def write_kv(cache, k_new, v_new, pos, *, ring: bool = False, window: int = 0):
    """k_new/v_new: (B, 1, Hkv, D); pos: scalar int32."""
    idx = ring_slot(pos, window) if ring else pos
    cache = dict(cache)
    cache["k"] = cache["k"].at[:, idx].set(k_new[:, 0])
    cache["v"] = cache["v"].at[:, idx].set(v_new[:, 0])
    return cache
