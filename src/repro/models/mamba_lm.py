"""Pure-SSM LM (mamba2-780m): attention-free, constant-size decode state."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pspec import constrain
from repro.models import ssm
from repro.models.layers import dense_init, rmsnorm


def init_layer(key, cfg):
    return {"norm": jnp.ones((cfg.d_model,), jnp.float32),
            "mamba": ssm.init_mamba(key, cfg)}


def init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(kl, cfg.num_layers))
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model),
                            jnp.dtype(cfg.dtype)),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size),
                              jnp.dtype(cfg.dtype)),
    }


def _head(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["lm_head"], "batch", None, "vocab")


def forward(params, batch, cfg, *, remat: bool = False, **_):
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    x = constrain(x, "batch", None, None)

    def body(x, lp):
        y = ssm.mamba_forward(lp["mamba"], rmsnorm(x, lp["norm"],
                                                   cfg.norm_eps), cfg)
        return x + y, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return _head(params, x, cfg), jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    one = ssm.init_mamba_cache(cfg, batch, dtype)
    return {
        "ssm": jax.tree.map(
            lambda a: jnp.zeros((cfg.num_layers,) + a.shape, a.dtype), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg, cache, **_):
    tokens = batch["tokens"]
    x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))

    def body(x, lp):
        y, st = ssm.mamba_forward(lp["mamba"],
                                  rmsnorm(x, lp["norm"], cfg.norm_eps),
                                  cfg, return_state=True)
        return x + y, st

    x, states = jax.lax.scan(body, x, params["layers"])
    cache = {"ssm": states, "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return _head(params, x[:, -1:], cfg), cache


def decode_step(params, cache, token, pos, cfg):
    """``pos`` may be scalar or a per-row (B,) vector (slot-table decode);
    the recurrence itself is position-free, so only the bookkeeping
    ``cache["pos"] = pos + 1`` changes shape."""
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))

    def body(x, lp_st):
        lp, st = lp_st
        y, st = ssm.mamba_step(lp["mamba"],
                               st, rmsnorm(x, lp["norm"], cfg.norm_eps), cfg)
        return x + y, st

    x, states = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
    return _head(params, x, cfg), {"ssm": states, "pos": pos + 1}
