"""Decoder-only transformer LM (llama-style): dense FFN or MoE FFN.

Covers assigned archs: deepseek-coder-33b, qwen3-14b, internlm2-20b,
minitron-4b (dense) and olmoe-1b-7b, kimi-k2-1t-a32b (MoE).

The layer stack is a ``lax.scan`` over parameters stacked on a leading L
axis, so HLO size and compile time are ~O(1) in depth (62-100 layer archs
compile in seconds — required for the 40x dry-run matrix).

Model API (shared by every family in models/):
  init(key, cfg)                                  -> params
  forward(params, batch, cfg, ...)                -> logits, aux
  init_cache(cfg, batch, max_len, dtype)          -> cache
  decode_step(params, cache, token, pos, cfg)     -> logits, cache
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pspec import constrain
from repro.models import kvcache, moe as moe_lib
from repro.models.layers import (attention, attn_out, attn_qkv, dense_init,
                                 init_attn, init_mlp, mlp, paged_attention,
                                 rmsnorm)


# ----------------------------------------------------------------- init

def init_layer(key, cfg):
    ka, km = jax.random.split(key)
    p = {"attn": init_attn(ka, cfg),
         "ln1": jnp.ones((cfg.d_model,), jnp.float32),
         "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(km, cfg)
    else:
        p["mlp"] = init_mlp(km, cfg)
    return p


def init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(kl, cfg.num_layers))
    p = {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model),
                            jnp.dtype(cfg.dtype)),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab_size),
                                  jnp.dtype(cfg.dtype))
    return p


# ----------------------------------------------------------------- blocks

def block(lp, x, cfg, *, attn_impl: str = "auto"):
    """Pre-norm attn + pre-norm FFN. Returns (y, aux_loss)."""
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn_qkv(lp["attn"], h, cfg)
    ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                    impl=attn_impl)
    x = x + attn_out(lp["attn"], ctx, cfg)
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = moe_lib.moe_ffn(lp["moe"], h, cfg)
    else:
        y, aux = mlp(lp["mlp"], h), jnp.zeros((), jnp.float32)
    x = x + y
    return constrain(x, "batch", None, None), aux


def _embed(params, tokens, cfg):
    x = params["embed"][tokens]          # (B,S,d)
    return constrain(x.astype(jnp.dtype(cfg.dtype)), "batch", None, None)


def _head(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if "lm_head" not in params else params["lm_head"]
    logits = x @ w
    return constrain(logits, "batch", None, "vocab")


def forward(params, batch, cfg, *, remat: bool = False,
            attn_impl: str = "auto"):
    """batch: {"tokens": (B,S) int32}. Returns (logits (B,S,V), aux)."""
    x = _embed(params, batch["tokens"], cfg)

    def body(carry, lp):
        y, aux = block(lp, carry, cfg, attn_impl=attn_impl)
        return y, aux

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, auxs = jax.lax.scan(body, x, params["layers"])
    return _head(params, x, cfg), auxs.sum()


# ----------------------------------------------------------------- decode

def cache_window(cfg, max_len: int) -> int:
    """Ring-buffer length: SWA archs hold only the window."""
    if cfg.sliding_window > 0:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    w = cache_window(cfg, max_len)
    one = kvcache.init_kv(batch, w, cfg.num_kv_heads, cfg.head_dim, dtype)
    return {
        "kv": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape), one),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, batch, cfg, cache, *, attn_impl: str = "auto",
            last_pos=None):
    """Run the full prompt, fill the cache, return last-token logits.

    For ring (SWA) caches only the last ``window`` positions are retained.
    ``last_pos`` (scalar or (B,)): index of the last REAL token when the
    prompt is right-padded to a bucket length — logits are gathered there
    instead of at position S-1. Padding rows beyond ``last_pos`` are
    causally invisible to real rows and their (garbage) cache entries stay
    masked by ``kv_len``/``kpos`` until decode overwrites them.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    w = cache["kv"]["k"].shape[2]
    x = _embed(params, tokens, cfg)

    def body(x, lp):
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg)
        ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                        impl=attn_impl)
        x = x + attn_out(lp["attn"], ctx, cfg)
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_lib.moe_ffn(lp["moe"], h, cfg)
        else:
            y = mlp(lp["mlp"], h)
        return x + y, {"k": kvcache.fit_prefill(k, w), "v": kvcache.fit_prefill(v, w)}

    x, kvs = jax.lax.scan(body, x, params["layers"])
    cache = {"kv": kvs, "pos": jnp.asarray(s, jnp.int32)}
    if last_pos is not None:
        last = jnp.broadcast_to(jnp.asarray(last_pos, jnp.int32), (b,))
        x = x[jnp.arange(b), last][:, None]
        return _head(params, x, cfg), cache
    return _head(params, x[:, -1:], cfg), cache


def decode_step(params, cache, token, pos, cfg, *,
                attn_backend: str = "gather"):
    """token: (B,1) int32; pos: scalar int32 (tokens generated so far) for
    the lockstep paths, or a (B,) vector for the slot-table decode — each
    row then reads/writes its own cursor.

    A cache carrying a ``"ptab"`` page table (the serve engine's paged
    layout — see models/kvcache.py) switches the KV write/read to the
    block-table path: scatter through the table, attend over gathered
    pages. Math is identical to the dense path, so outputs are
    token-identical. An optional ``"wtab"`` write table redirects the KV
    SCATTER only (attention still gathers through ``ptab``) — the mixed
    token-slot step uses it to recompute positions whose KV already
    lives in shared prefix pages without rewriting pages other slots
    read (rows redirected to the null page 0). ``attn_backend`` picks
    the paged-attention execution path — ``"gather"`` (XLA gather +
    dense mask) or ``"pallas"`` (fused flash-decoding kernel); see
    layers.paged_attention.

    Returns (logits (B,1,V), new cache).
    """
    x = _embed(params, token, cfg)
    paged = "ptab" in cache
    w = cache["kv"]["k"].shape[2]
    ring = not paged and cfg.sliding_window > 0 and w == cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    batched_pos = pos.ndim > 0
    positions = pos[:, None] if batched_pos else \
        jnp.full((token.shape[0], 1), pos)

    from repro.models.cp_attention import cp_available, cp_decode_attention
    use_cp = (cfg.cp_decode and not ring and not paged and not batched_pos
              and cp_available(cache["kv"]["k"][0]))

    def body(x, lp_kv):
        lp, kv = lp_kv
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn_qkv(lp["attn"], h, cfg, positions=positions)
        if paged:
            kv = kvcache.write_kv_paged(kv, k, v,
                                        cache.get("wtab", cache["ptab"]),
                                        positions[:, 0])
            ctx = paged_attention(q, kv["k"], kv["v"], cache["ptab"],
                                  positions[:, 0], backend=attn_backend)
        elif use_cp:
            # context-parallel: shard-local write + psum-softmax combine
            ctx, kv = cp_decode_attention(q, kv, k, v, pos,
                                          window=cfg.sliding_window)
        else:
            kv = kvcache.write_kv(kv, k, v, pos, ring=ring, window=w)
            kpos = kvcache.ring_kpos(positions, w) if ring else None
            kv_len = None if ring else jnp.minimum(pos + 1, w)
            ctx = attention(q, kv["k"], kv["v"], causal=True,
                            window=cfg.sliding_window, q_offset=pos,
                            kv_len=kv_len, kpos=kpos)
        x = x + attn_out(lp["attn"], ctx, cfg)
        h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            y, _ = moe_lib.moe_ffn(lp["moe"], h, cfg)
        else:
            y = mlp(lp["mlp"], h)
        return x + y, kv

    x, kvs = jax.lax.scan(body, x, (params["layers"], cache["kv"]))
    out = {"kv": kvs, "pos": pos + 1}
    if paged:
        out["ptab"] = cache["ptab"]
    return _head(params, x, cfg), out
