"""Llama-3.2-Vision-style VLM backbone: a dense decoder where every
``cross_attn_every`` self-attention layers are followed by one gated
cross-attention layer over image patch embeddings [hf:Llama-3.2-Vision].

The ViT tower + projector are STUBBED (assignment carve-out):
``batch["image_embeds"]`` carries (B, num_image_tokens, d_model).

Structure (scanned over G groups, O(1) HLO in depth):
    G = num_layers // (cross_attn_every + 1) groups of
        [cross_attn_every x self-layer] -> 1 cross-layer
    + trailing self layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pspec import constrain
from repro.models import kvcache
from repro.models.layers import (attention, attn_out, attn_qkv, dense_init,
                                 init_attn, init_mlp, mlp, rmsnorm)
from repro.models.transformer import cache_window, init_layer
from repro.models.encdec import cross_kv as _cross_kv_proj


def _gl(cfg):
    per = cfg.cross_attn_every + 1
    g = cfg.num_layers // per
    rest = cfg.num_layers - g * per
    return g, rest


def init_cross_layer(key, cfg):
    kc, km = jax.random.split(key)
    return {"xattn": init_attn(kc, cfg), "mlp": init_mlp(km, cfg),
            "lnx": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "gate_attn": jnp.zeros((), jnp.float32),
            "gate_mlp": jnp.zeros((), jnp.float32)}


def init(key, cfg):
    ke, kg, kc, kr, kh = jax.random.split(key, 5)
    g, rest = _gl(cfg)
    selfs = jax.vmap(jax.vmap(lambda k: init_layer(k, cfg)))(
        jax.random.split(kg, (g, cfg.cross_attn_every)))
    crosses = jax.vmap(lambda k: init_cross_layer(k, cfg))(
        jax.random.split(kc, g))
    trailing = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(kr, max(rest, 1)))
    return {
        "embed": dense_init(ke, (cfg.vocab_size, cfg.d_model),
                            jnp.dtype(cfg.dtype)),
        "self_groups": selfs, "cross": crosses, "trailing": trailing,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size),
                              jnp.dtype(cfg.dtype)),
    }


def _self_block(lp, x, cfg, *, attn_impl="auto", positions=None, kv=None,
                pos=None, w=0, ring=False, use_cp=False):
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn_qkv(lp["attn"], h, cfg, positions=positions)
    if kv is None:
        ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                        impl=attn_impl)
        new_kv = (k, v)
    elif use_cp:
        from repro.models.cp_attention import cp_decode_attention
        ctx, new_kv = cp_decode_attention(q, kv, k, v, pos,
                                          window=cfg.sliding_window)
    else:
        kv = kvcache.write_kv(kv, k, v, pos, ring=ring, window=w)
        kpos = kvcache.ring_kpos(positions, w) if ring else None
        kv_len = None if ring else jnp.minimum(pos + 1, w)
        ctx = attention(q, kv["k"], kv["v"], causal=True,
                        window=cfg.sliding_window, q_offset=pos,
                        kv_len=kv_len, kpos=kpos)
        new_kv = kv
    x = x + attn_out(lp["attn"], ctx, cfg)
    x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x, new_kv


def _cross_block(cp, x, img_kv, cfg):
    """Gated cross-attention (gates init 0 => vision is a no-op at init,
    as in the source model)."""
    h = rmsnorm(x, cp["lnx"], cfg.norm_eps)
    b, s, _ = h.shape
    q = (h @ cp["xattn"]["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    ctx = attention(q, img_kv["k"], img_kv["v"], causal=False, impl="full")
    x = x + (jnp.tanh(cp["gate_attn"]).astype(x.dtype)
             * attn_out(cp["xattn"], ctx, cfg))
    h = rmsnorm(x, cp["ln2"], cfg.norm_eps)
    x = x + jnp.tanh(cp["gate_mlp"]).astype(x.dtype) * mlp(cp["mlp"], h)
    return x


def _head(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(x @ params["lm_head"], "batch", None, "vocab")


def forward(params, batch, cfg, *, remat: bool = False, attn_impl="auto"):
    """batch: {"tokens": (B,S), "image_embeds": (B,N_img,d)}."""
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    img = batch["image_embeds"].astype(x.dtype)
    g, rest = _gl(cfg)

    def group(x, glp):
        slp, cp = glp

        def inner(x, lp):
            y, _ = _self_block(lp, x, cfg, attn_impl=attn_impl)
            return y, None

        x, _ = jax.lax.scan(inner, x, slp)
        img_kv = _cross_kv_proj(cp, img, cfg)
        return _cross_block(cp, x, img_kv, cfg), None

    if remat:
        group = jax.checkpoint(group, prevent_cse=False)
    x, _ = jax.lax.scan(group, x, (params["self_groups"], params["cross"]))
    if rest:
        def inner(x, lp):
            y, _ = _self_block(lp, x, cfg, attn_impl=attn_impl)
            return y, None
        x, _ = jax.lax.scan(inner, x, params["trailing"])
    return _head(params, x, cfg), jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    g, rest = _gl(cfg)
    w = cache_window(cfg, max_len)
    kv = kvcache.init_kv(batch, w, cfg.num_kv_heads, cfg.head_dim, dtype)
    xkv = kvcache.init_kv(batch, cfg.num_image_tokens, cfg.num_kv_heads,
                          cfg.head_dim, dtype)
    stack = lambda t, n: jax.tree.map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), t)
    return {"kv_g": stack(kv, g * cfg.cross_attn_every),
            "kv_t": stack(kv, max(rest, 1)),
            "xkv": stack(xkv, g),
            "pos": jnp.zeros((), jnp.int32)}


def prefill(params, batch, cfg, cache, *, attn_impl="auto"):
    x = params["embed"][batch["tokens"]].astype(jnp.dtype(cfg.dtype))
    img = batch["image_embeds"].astype(x.dtype)
    s = batch["tokens"].shape[1]
    g, rest = _gl(cfg)
    w = cache["kv_g"]["k"].shape[2]

    def group(x, glp):
        slp, cp = glp

        def inner(x, lp):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], h, cfg)
            ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                            impl=attn_impl)
            x = x + attn_out(lp["attn"], ctx, cfg)
            x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return x, {"k": kvcache.fit_prefill(k, w), "v": kvcache.fit_prefill(v, w)}

        x, kvs = jax.lax.scan(inner, x, slp)
        img_kv = _cross_kv_proj(cp, img, cfg)
        return _cross_block(cp, x, img_kv, cfg), (kvs, img_kv)

    x, (kv_g, xkvs) = jax.lax.scan(group, x,
                                   (params["self_groups"], params["cross"]))
    kv_g = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), kv_g)
    if rest:
        def inner(x, lp):
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, k, v = attn_qkv(lp["attn"], h, cfg)
            ctx = attention(q, k, v, causal=True, window=cfg.sliding_window,
                            impl=attn_impl)
            x = x + attn_out(lp["attn"], ctx, cfg)
            x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
            return x, {"k": kvcache.fit_prefill(k, w), "v": kvcache.fit_prefill(v, w)}
        x, kv_t = jax.lax.scan(inner, x, params["trailing"])
    else:
        kv_t = jax.tree.map(lambda a: a[None],
                            kvcache.init_kv(x.shape[0], w, cfg.num_kv_heads,
                                            cfg.head_dim, x.dtype))
    cache = {"kv_g": kv_g, "kv_t": kv_t, "xkv": xkvs,
             "pos": jnp.asarray(s, jnp.int32)}
    return _head(params, x[:, -1:], cfg), cache


def decode_step(params, cache, token, pos, cfg):
    """``pos``: scalar (lockstep) or (B,) per-row vector (slot-table)."""
    from repro.models.cp_attention import cp_available
    x = params["embed"][token].astype(jnp.dtype(cfg.dtype))
    g, rest = _gl(cfg)
    w = cache["kv_g"]["k"].shape[2]
    ring = cfg.sliding_window > 0 and w == cfg.sliding_window
    pos = jnp.asarray(pos, jnp.int32)
    use_cp = (cfg.cp_decode and not ring and not pos.ndim
              and cp_available(cache["kv_g"]["k"][0]))
    positions = pos[:, None] if pos.ndim else \
        jnp.full((token.shape[0], 1), pos)
    e = cfg.cross_attn_every
    kv_g = jax.tree.map(lambda a: a.reshape((g, e) + a.shape[1:]),
                        cache["kv_g"])

    def group(x, inp):
        (slp, cp), kvs, xkv = inp

        def inner(x_, lp_kv):
            lp, kv = lp_kv
            y, kv = _self_block(lp, x_, cfg, positions=positions, kv=kv,
                                pos=pos, w=w, ring=ring, use_cp=use_cp)
            return y, kv

        x, kvs = jax.lax.scan(inner, x, (slp, kvs))
        return _cross_block(cp, x, xkv, cfg), (kvs, xkv)

    x, (kv_g, _) = jax.lax.scan(
        group, x, ((params["self_groups"], params["cross"]), kv_g,
                   cache["xkv"]))
    kv_g = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), kv_g)
    kv_t = cache["kv_t"]
    if rest:
        def inner(x_, lp_kv):
            lp, kv = lp_kv
            y, kv = _self_block(lp, x_, cfg, positions=positions, kv=kv,
                                pos=pos, w=w, ring=ring, use_cp=use_cp)
            return y, kv
        x, kv_t = jax.lax.scan(inner, x, (params["trailing"], cache["kv_t"]))
    new = {"kv_g": kv_g, "kv_t": kv_t, "xkv": cache["xkv"], "pos": pos + 1}
    return _head(params, x, cfg), new
