"""Optimizers, from scratch in JAX (no optax on the box).

* AdamW with fp32 master weights + fp32 m/v — Megatron-style mixed
  precision; states are ZeRO-1 shardable (core/sharding.opt_state_pspecs).
* Adafactor (factored second moments, no momentum, no master copy) — the
  low-memory option the planner picks for the 1T-param MoE (DESIGN.md §4.1).
* global-norm clipping + cosine schedule with linear warmup.

All functions are pure pytree -> pytree; the trainer jits them inside
train_step.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def cosine_lr(step, *, base_lr: float, warmup: int, total: int,
              min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ------------------------------------------------------------------- AdamW

def adamw_init(params):
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        new_master = master - lr * (mhat / (jnp.sqrt(vhat) + eps)
                                    + weight_decay * master)
        return m, v, new_master

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"])
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    return new_params, {"m": m, "v": v, "master": master, "step": step}


# --------------------------------------------------------------- Adafactor

def _factored_dims(shape):
    """Last two non-trivial dims, if the tensor is big enough to factor."""
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def adafactor_init(params):
    """Parallel vr/vc trees (full-rank v lives in vr with a dummy vc) so
    every tree in the update has the same structure as ``params``."""
    def vr(p):
        if _factored_dims(p.shape) is None:
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros(p.shape[:-1], jnp.float32)

    def vc(p):
        if _factored_dims(p.shape) is None:
            return jnp.zeros((), jnp.float32)
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

    return {"vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** (-decay)

    def upd(g, vr, vc, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored_dims(p.shape) is not None:
            vr = beta * vr + (1 - beta) * g2.mean(-1)
            vc = beta * vc + (1 - beta) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1)[..., None, None], eps))
            u = g * jax.lax.rsqrt(denom + eps)
        else:
            vr = beta * vr + (1 - beta) * g2
            u = g * jax.lax.rsqrt(vr + eps)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / clip_threshold)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * u - lr * weight_decay * p32
        return new_p.astype(p.dtype), vr, vc

    out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), {"vr": pick(1), "vc": pick(2), "step": step}


def get_optimizer(name: str):
    return {"adamw": (adamw_init, adamw_update),
            "adafactor": (adafactor_init, adafactor_update)}[name]
