"""Production meshes. Functions, not module constants — importing this
module never touches jax device state.

``make_mesh`` is the version-compatible constructor every caller should
use: newer jax wants explicit ``axis_types`` (Auto) for the sharded-under-
pjit meshes we build, older jax (< 0.5) has no ``AxisType`` at all.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence] = None):
    """jax.make_mesh across jax versions (with/without AxisType.Auto)."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:              # jax < 0.5: no AxisType, Auto implied
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devices)
    return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                         axis_types=axis_types)


_mk = make_mesh                         # backwards-compatible alias


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); multi_pod adds a 2-pod axis.

    The axis roles follow the paper's case-studies: intra-operator (tensor)
    parallelism on the fast innermost "model" axis, data parallelism on
    "data", and pods connected by DCN carry only data parallelism
    (PaLM §5.3: 2x data parallel across pods, no inter-layer parallelism).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1, data: int = 0):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    if data == 0:
        data = n // model
    return make_mesh((data, model), ("data", "model"))


def make_pipeline_mesh(*, data: int, pipe: int, model: int,
                       devices: Optional[Sequence] = None):
    """Mesh with an explicit inter-operator ("pipe") axis for
    core/pipeline.py — the survey's hybrid dp x pp x tp layout (Table 2)."""
    return make_mesh((data, pipe, model), ("data", "pipe", "model"),
                     devices=devices)
