"""Production meshes. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def _mk(shape, axes):
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data","model"); multi_pod adds a 2-pod axis.

    The axis roles follow the paper's case-studies: intra-operator (tensor)
    parallelism on the fast innermost "model" axis, data parallelism on
    "data", and pods connected by DCN carry only data parallelism
    (PaLM §5.3: 2x data parallel across pods, no inter-layer parallelism).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(*, model: int = 1, data: int = 0):
    """Small mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    if data == 0:
        data = n // model
    return _mk((data, model), ("data", "model"))


def make_pipeline_mesh(*, data: int, pipe: int, model: int):
    """Mesh with an explicit inter-operator ("pipe") axis for
    core/pipeline.py — the survey's hybrid dp x pp x tp layout (Table 2)."""
    return _mk((data, pipe, model), ("data", "pipe", "model"))
