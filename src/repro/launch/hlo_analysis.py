"""Loop-aware HLO analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which makes it
useless for scan-over-layers programs (a 62-layer model reports ~1 layer of
FLOPs). This module parses ``compiled.as_text()`` into computations, counts
dot FLOPs / buffer bytes / collective bytes per computation, and propagates
multipliers along the call graph using the ``known_trip_count`` backend
config XLA attaches to scan-derived while loops.

Outputs feed launch/roofline.py. Counting rules:
  * FLOPs: dot ops: 2 * prod(result dims) * K  (K = contracted size);
    elementwise ops are ignored (matmul-dominated workloads).
  * bytes: every op's RESULT bytes once (proxy for HBM writes) plus
    operand bytes for dot/gather/scatter/collectives (proxy for reads);
    intra-fusion ops are skipped (they never hit HBM).
  * collective bytes: result bytes of all-gather/all-reduce/reduce-scatter/
    all-to-all/collective-permute ops, split by whether the replica group
    crosses the "pod" axis (DCN) or not (ICI).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_shape(txt: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    m = _SHAPE_RE.search(txt)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def shape_bytes(txt: str) -> int:
    """Total bytes over every dtype[shape] group in a (possibly tuple)
    type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: List[str]
    attrs: str
    is_root: bool = False

    def result_bytes(self) -> int:
        return shape_bytes(self.result_type)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # op name -> type
    calls: List[Tuple[str, float]] = field(default_factory=list)
    is_fusion_body: bool = False

    def flops(self) -> float:
        total = 0.0
        for op in self.ops:
            if op.opcode not in ("dot", "convolution"):
                continue
            _, rdims = parse_shape(op.result_type)
            rn = 1
            for d in rdims:
                rn *= d
            k = 1
            m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
            if m and op.operands:
                lhs_t = self.symbols.get(op.operands[0], "")
                _, ldims = parse_shape(lhs_t)
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        k *= ldims[int(ci)]
            total += 2.0 * rn * k
        return total

    def bytes_accessed(self, dus_map=None) -> float:
        if self.is_fusion_body:
            return 0.0
        dus_map = dus_map or {}
        total = 0.0
        for op in self.ops:
            # control-flow results are aliases of their body outputs (already
            # counted inside the body x trip count); tuples/gte are free.
            if op.opcode in ("parameter", "constant", "tuple",
                             "get-tuple-element", "bitcast", "while",
                             "conditional", "call", "custom-call"):
                continue
            if op.opcode in ("fusion", "dynamic-update-slice"):
                # in-place DUS: XLA aliases output to the big operand and
                # writes only the update slice — charge the slice, not the
                # whole buffer (scan ys / cache writes would otherwise be
                # overcounted by O(depth)).
                upd = None
                if op.opcode == "dynamic-update-slice" and len(op.operands) > 1:
                    upd = shape_bytes(self.symbols.get(op.operands[1], ""))
                else:
                    for callee in re.findall(r"calls=%?([\w.\-]+)", op.attrs):
                        if callee in dus_map:
                            upd = dus_map[callee]
                            break
                if upd is not None and upd > 0:
                    total += 2 * upd          # read slice env + write slice
                    continue
            total += op.result_bytes()
            if op.opcode in ("dot", "gather", "scatter", "fusion",
                             *COLLECTIVES):
                for o in op.operands:
                    total += shape_bytes(self.symbols.get(o, ""))
        return total

    def collective_bytes(self) -> Dict[str, float]:
        out: Dict[str, float] = defaultdict(float)
        for op in self.ops:
            if op.opcode in COLLECTIVES:
                cross_pod = _crosses_pod(op.attrs)
                key = op.opcode + ("@dcn" if cross_pod else "")
                out[key] += op.result_bytes()
        return dict(out)


def _crosses_pod(attrs: str) -> bool:
    """Heuristic: a replica group spanning devices >= 256 apart crosses the
    pod axis of the (2,16,16) mesh (pods are the slowest-varying axis)."""
    m = re.search(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}", attrs)
    if not m:
        m2 = re.search(r"replica_groups=\[\d+,\d+\]<=\[(\d+)\]", attrs)
        if m2:
            return False  # iota groups along minor axes
        return False
    first = m.group(1).split("}")[0].strip("{")
    ids = [int(x) for x in first.split(",") if x.strip()]
    return bool(ids) and (max(ids) - min(ids)) >= 256


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{$")
_OP_HDR = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_SIMPLE_TYPE = re.compile(r"^([a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*")
_OPCODE = re.compile(r"^([\w\-]+)\(")


def _parse_op_line(line: str):
    """Parse '%name = TYPE opcode(operands), attrs'. Tuple types may embed
    /*index=N*/ comments, so the type is paren-walked, not regexed."""
    m = _OP_HDR.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        else:
            return None
        rtype, rest = rest[:i + 1], rest[i + 1:].lstrip()
    else:
        tm = _SIMPLE_TYPE.match(rest)
        if not tm:
            return None
        rtype, rest = tm.group(1), rest[tm.end():]
    om = _OPCODE.match(rest)
    if not om:
        return None
    opcode = om.group(1)
    rest = rest[om.end():]
    depth = 1
    idx = len(rest)
    for idx, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    operand_txt, attrs = rest[:idx], rest[idx + 1:]
    operands = [o.strip().lstrip("%") for o in operand_txt.split(",")
                if o.strip().startswith("%")]
    # inline-typed operands: "f32[8]{0} %foo" — grab trailing %name tokens
    if not operands:
        operands = [t.lstrip("%") for t in
                    re.findall(r"%([\w.\-]+)", operand_txt)]
    return name, rtype, opcode, operands, attrs
_TRIP_RE = re.compile(r'known_trip_count"?\s*[:=]\s*\{"?n"?\s*:\s*"?(\d+)"?\}')
# callee lists: key=%name  or  key={%a, %b}; continuation items REQUIRE the
# leading % so we never swallow the following attribute (e.g. metadata=).
_CALL_RE = re.compile(
    r"(?:condition|body|to_apply|calls|branch_computations|"
    r"true_computation|false_computation)="
    r"(\{[^}]*\}|%?[\w.\-]+)")


def parse_module(txt: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in txt.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = m.group(2)
                if "fused_computation" in m.group(2) or \
                        m.group(2).startswith("fused."):
                    cur.is_fusion_body = True
                comps[cur.name] = cur
            continue
        if line == "}":
            cur = None
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, rtype, opcode, operands, attrs = parsed
        op = Op(name, opcode, rtype, operands, attrs,
                is_root=line.startswith("ROOT "))
        cur.ops.append(op)
        cur.symbols[name] = rtype
        # call edges with multipliers
        mult = 1.0
        if opcode == "while":
            tm = _TRIP_RE.search(attrs)
            mult = float(tm.group(1)) if tm else 1.0
        for cm in _CALL_RE.finditer(attrs):
            blob = cm.group(1)
            for callee in re.findall(r"%?([\w.\-]+)", blob):
                if callee:
                    cur.calls.append((callee, mult))
    return comps, entry


@dataclass
class HloSummary:
    flops: float
    bytes_accessed: float
    collectives: Dict[str, float]
    collective_bytes_ici: float
    collective_bytes_dcn: float
    num_while: int


def analyze(txt: str) -> HloSummary:
    comps, entry = parse_module(txt)
    if entry is None:
        entry = next(iter(comps))
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, seen):
        if name not in comps or name in seen:
            return
        mult[name] += m
        for callee, cm in comps[name].calls:
            visit(callee, m * cm, seen | {name})

    visit(entry, 1.0, frozenset())

    # fusion bodies rooted at dynamic-update-slice are in-place: map callee
    # name -> update-operand bytes
    dus_map = {}
    for name, comp in comps.items():
        if not comp.is_fusion_body:
            continue
        roots = [op for op in comp.ops if op.is_root]
        if roots and roots[-1].opcode == "dynamic-update-slice":
            r = roots[-1]
            if len(r.operands) > 1:
                dus_map[name] = shape_bytes(comp.symbols.get(r.operands[1],
                                                             ""))

    flops = 0.0
    byts = 0.0
    colls: Dict[str, float] = defaultdict(float)
    n_while = 0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        flops += m * comp.flops()
        byts += m * comp.bytes_accessed(dus_map)
        for k, v in comp.collective_bytes().items():
            colls[k] += m * v
        n_while += sum(1 for op in comp.ops if op.opcode == "while")
    ici = sum(v for k, v in colls.items() if not k.endswith("@dcn"))
    dcn = sum(v for k, v in colls.items() if k.endswith("@dcn"))
    return HloSummary(flops=flops, bytes_accessed=byts,
                      collectives=dict(colls), collective_bytes_ici=ici,
                      collective_bytes_dcn=dcn, num_while=n_while)
