"""Roofline-term extraction from a compiled dry-run (deliverable g).

    compute    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory     = HLO_bytes   / (chips * HBM_BW)
    collective = ici_bytes/dev / (ICI_BW * LINKS)  +  dcn_bytes/dev / DCN_BW

FLOPs / bytes / collective bytes come from launch/hlo_analysis.py — a
loop-aware parse of ``compiled.as_text()`` (XLA's ``cost_analysis()``
counts scan bodies once; see hlo_analysis docstring). The post-SPMD HLO is
the PER-DEVICE program, so globals are per-device values x chips.
``cost_analysis()`` raw numbers are kept for cross-reference.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (2 usable links per axis-collective), 25 GB/s/chip DCN.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.launch import hlo_analysis as ha

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
ICI_LINKS = 2                # usable links per chip for a 1-axis collective
DCN_BW = 25e9                # bytes/s / chip across pods


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float             # global (= per-device x chips)
    hlo_bytes: float             # global
    coll_ici_dev: float          # bytes per device over ICI
    coll_dcn_dev: float          # bytes per device over DCN
    model_flops: float
    coll_detail: Dict[str, float] = field(default_factory=dict)
    mem_per_device: float = 0.0
    xla_cost_analysis: Dict[str, float] = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return (self.coll_ici_dev / (ICI_BW * ICI_LINKS)
                + self.coll_dcn_dev / DCN_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def step_time_lower_bound(self) -> float:
        """max of the three terms = perfectly-overlapped step time."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu_upper_bound(self) -> float:
        t = self.step_time_lower_bound
        if t == 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_ici_bytes_per_dev": self.coll_ici_dev,
            "coll_dcn_bytes_per_dev": self.coll_dcn_dev,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_upper_bound": self.mfu_upper_bound,
            "mem_per_device_gb": self.mem_per_device / 1e9,
            "coll_detail": self.coll_detail,
            "xla_cost_analysis": self.xla_cost_analysis,
        }


def model_flops_train(cfg, tokens: int) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) — the MFU numerator (paper §6)."""
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens


def extract(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    txt = compiled.as_text()
    summary = ha.analyze(txt)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        xla_cost = {"flops": float(cost.get("flops", 0.0)),
                    "bytes accessed": float(cost.get("bytes accessed", 0.0))}
    except Exception:
        xla_cost = {}
    try:
        mem = compiled.memory_analysis()
        per_dev = (getattr(mem, "temp_size_in_bytes", 0)
                   + getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        per_dev = 0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=summary.flops * chips,
        hlo_bytes=summary.bytes_accessed * chips,
        coll_ici_dev=summary.collective_bytes_ici,
        coll_dcn_dev=summary.collective_bytes_dcn,
        model_flops=model_flops,
        coll_detail=summary.collectives,
        mem_per_device=per_dev,
        xla_cost_analysis=xla_cost)
