"""ShapeDtypeStruct stand-ins + shardings for every model input — the
no-allocation inputs the dry-run lowers against (deliverable e)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import sharding as shd
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.step import init_opt_state, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    out = {"tokens": _sds((batch, seq), jnp.int32)}
    if cfg.has_encoder:
        out["frames"] = _sds((batch, cfg.encoder_ctx, cfg.d_model),
                             jnp.float32)
    if cfg.cross_attn_every > 0:
        out["image_embeds"] = _sds((batch, cfg.num_image_tokens, cfg.d_model),
                                   jnp.float32)
    return out


def batch_shardings(cfg, batch: int, mesh: Mesh, strategy: Strategy):
    rules = strategy.rules(mesh)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    bspec = rules["batch"] if batch % dp == 0 else None
    out = {"tokens": NamedSharding(mesh, P(bspec, None))}
    if cfg.has_encoder:
        out["frames"] = NamedSharding(mesh, P(bspec, None, None))
    if cfg.cross_attn_every > 0:
        out["image_embeds"] = NamedSharding(mesh, P(bspec, None, None))
    return out


def abstract_params(cfg: ModelConfig):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init(jax.random.key(0), cfg))


def abstract_opt_state(cfg: ModelConfig, strategy: Strategy):
    params = abstract_params(cfg)
    return jax.eval_shape(lambda p: init_opt_state(p, strategy), params)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    model = get_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(cfg, batch, max_len))


def train_specs(cfg, shape: ShapeConfig, mesh: Mesh, strategy: Strategy):
    """(args, in_shardings) for train_step(params, opt_state, batch)."""
    params = abstract_params(cfg)
    opt = abstract_opt_state(cfg, strategy)
    batch = batch_specs(cfg, shape.global_batch, shape.seq_len)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       shd.param_pspecs(params, strategy, mesh))
    osh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       shd.opt_state_pspecs(opt, params, strategy, mesh))
    bsh = batch_shardings(cfg, shape.global_batch, mesh, strategy)
    return (params, opt, batch), (psh, osh, bsh)


def prefill_specs(cfg, shape: ShapeConfig, mesh: Mesh, strategy: Strategy):
    params = abstract_params(cfg)
    batch = batch_specs(cfg, shape.global_batch, shape.seq_len)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       shd.param_pspecs(params, strategy, mesh))
    bsh = batch_shardings(cfg, shape.global_batch, mesh, strategy)
    return (params, batch), (psh, bsh)


def decode_specs(cfg, shape: ShapeConfig, mesh: Mesh, strategy: Strategy):
    """(args, shardings) for serve_step(params, cache, token, pos) — one new
    token with a KV/SSM cache of seq_len."""
    params = abstract_params(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    token = _sds((shape.global_batch, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       shd.param_pspecs(params, strategy, mesh))
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       shd.cache_pspecs(cache, strategy, mesh,
                                        shape.global_batch))
    rules = strategy.rules(mesh)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    bspec = rules["batch"] if shape.global_batch % dp == 0 else None
    tsh = NamedSharding(mesh, P(bspec, None))
    possh = NamedSharding(mesh, P())
    return (params, cache, token, pos), (psh, csh, tsh, possh)
