"""Training launcher: ``--arch`` x strategy on the local (or forced-count)
device mesh, driven through the ``repro.api.Session`` facade. For the
production 256/512-chip meshes use dryrun.py; this driver actually
executes steps (reduced config by default, since the box is CPU).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b \
        --steps 50 --smoke                        # reduced variant, runs
    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b --plan
"""
from __future__ import annotations

import argparse

from repro.api import Session, Strategy, TrainConfig, plan
from repro.configs import ARCH_NAMES, SHAPES, get_config, get_smoke
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced per-arch smoke config (default on "
                         "CPU; full configs are dry-run only)")
    ap.add_argument("--plan", action="store_true",
                    help="print the planner's production-mesh strategy and "
                         "exit")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    if args.plan:
        cfg = get_config(args.arch)
        p = plan(cfg, SHAPES["train_4k"], 256, method="dp")
        print(f"{args.arch}: {p.summary()}")
        return

    cfg = get_smoke(args.arch).with_(dtype="float32")
    strategy = Strategy(remat=False, microbatches=args.microbatches,
                        seq_parallel=args.seq_parallel, fsdp=args.fsdp,
                        dtype="float32")
    session = Session(cfg, strategy, make_host_mesh(model=1))
    tc = TrainConfig(steps=args.steps, lr=args.lr, log_every=10,
                     checkpoint_every=args.steps if args.checkpoint_dir
                     else 0,
                     checkpoint_dir=args.checkpoint_dir or "checkpoints")
    trainer = session.train(tc, global_batch=args.global_batch,
                            seq_len=args.seq)
    trainer.run()


if __name__ == "__main__":
    main()
