"""Serving launcher: spin up the continuous-batching engine on a reduced
config (through ``repro.api.Session`` — the session owns param init) and
stream a synthetic request workload through it.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --requests 6 --max-new 12

The engine defaults to the paged (block-table) KV cache wherever it is
exact; ``--dense`` forces the contiguous per-slot layout, ``--page-size``
/ ``--kv-pages`` shape the paged pool, ``--prefix-cache`` shares common
prompt prefixes copy-on-write (pair with ``--shared-prefix N`` for a
visible hit rate), and ``--lazy`` grows reservations on page-boundary
crossings with preempt/requeue under pressure. Audio (enc-dec) archs
serve with synthetic frame embeddings standing in for the stubbed
mel+conv frontend; VLM archs likewise serve with synthetic image patch
embeddings (the stubbed ViT+projector's output). On the paged layout
the engine steps in MIXED mode by default — one program per step over a
``--chunk-tokens`` token budget shared between decode and chunked
prefill (``--no-mixed`` restores the legacy split prefill/decode
programs); ``--spec-k K`` adds speculative multi-token decode — up to K
self-drafted tokens per slot verified in the same dispatch
(``--drafter ngram|model``), greedy output bit-identical.

Parallel serving (serve/parallel.py): ``--tp N`` shards the one-trace
decode program over N devices (Megatron layout, head-sharded KV pool),
``--dp M`` replicates the engine M times behind a least-load router —
``--tp 2 --dp 2`` needs 4 devices. On a CPU host the launcher forces 8
virtual devices up front (before jax initializes) so both flags work out
of the box; set XLA_FLAGS yourself to override.

ONLINE mode (``--serve``) skips the synthetic batch and stands up the
HTTP front-end (serve/server.py) on ``--port`` instead: ``POST
/generate`` with optional chunked token streaming, ``GET /metrics``
(Prometheus text — TTFT/TPOT p50/p90/p99, step latency, pool/prefix/
preemption counters), ``GET /healthz``; ``--watchdog-timeout`` arms the
stalled-step watchdog (diagnostic dump + cancel-and-requeue recovery).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --serve --port 8000 --watchdog-timeout 30
    curl -s localhost:8000/generate -d '{"prompt": [1,2,3], "max_new": 8}'
    curl -s localhost:8000/metrics | grep serve_ttft
"""
from __future__ import annotations

import os
import sys

if any(a.startswith(("--tp", "--dp")) for a in sys.argv):
    # must land before jax (imported below via repro.api) initializes
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np

from repro.api import Session
from repro.configs import ARCH_NAMES, get_smoke


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-14b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; >0 = on-device temperature sampling")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV layout "
                         "(default: paged block tables where exact)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="shared pool size in pages (default: dense-"
                         "capacity parity, slots*ceil(max_len/page_size))")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share one physical copy of common prompt "
                         "prefixes via refcounted pages (paged layout)")
    ap.add_argument("--lazy", action="store_true",
                    help="lazy page growth: reserve prompt + one decode "
                         "page at admission, grow on page-boundary "
                         "crossings, preempt/requeue when the pool runs "
                         "dry (paged layout)")
    ap.add_argument("--no-mixed", action="store_true",
                    help="disable the unified mixed token-slot step and "
                         "run the legacy split prefill/decode programs "
                         "(mixed is the default on the paged layout)")
    ap.add_argument("--chunk-tokens", type=int, default=256,
                    help="mixed step token budget: decode tokens for all "
                         "active slots plus prefill chunks share this "
                         "many tokens per step (must be >= --slots)")
    ap.add_argument("--attn-backend", choices=("gather", "pallas"),
                    default="gather",
                    help="paged-attention decode path: 'gather' (XLA "
                         "gather + dense mask) or 'pallas' (fused flash-"
                         "decoding kernel walking the page table; "
                         "interpret mode on CPU; needs the paged layout)")
    ap.add_argument("--spec-k", type=int, default=0, metavar="K",
                    help="speculative decode: draft up to K tokens per "
                         "slot per step and verify them in the same "
                         "mixed dispatch (0 disables; greedy only, "
                         "needs the mixed step)")
    ap.add_argument("--drafter", choices=("ngram", "model"),
                    default="ngram",
                    help="--spec-k drafter: 'ngram' prompt lookup "
                         "(free, self-speculative) or 'model' (tiny "
                         "greedy draft model; fresh params — "
                         "demonstrates plumbing, drafts at chance)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend the same N-token system prompt to every "
                         "request (demonstrates --prefix-cache sharing)")
    ap.add_argument("--tp", type=int, default=1,
                    help="intra-operator (tensor) parallel degree: shard "
                         "the decode program + KV pool over this many "
                         "devices (serve/parallel.py)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replica count: run this many "
                         "engine replicas behind a least-load router")
    ap.add_argument("--serve", action="store_true",
                    help="ONLINE mode: skip the synthetic batch and "
                         "expose the engine over HTTP — POST /generate "
                         "(set \"stream\": true for chunked per-token "
                         "streaming), GET /metrics (Prometheus text: "
                         "TTFT/TPOT percentiles, step latency, pool "
                         "counters), GET /healthz")
    ap.add_argument("--port", type=int, default=8000,
                    help="--serve: TCP port to bind (0 picks a free "
                         "port, printed at startup)")
    ap.add_argument("--host", default="127.0.0.1",
                    help="--serve: bind address")
    ap.add_argument("--watchdog-timeout", type=float, default=30.0,
                    help="--serve: seconds one engine step may run "
                         "before the watchdog logs a flight-recorder "
                         "dump and cancels-and-requeues the active "
                         "slots via the preemption path "
                         "(<= 0 disables the watchdog)")
    ap.add_argument("--trace-level", type=int, choices=(0, 1, 2),
                    default=1,
                    help="tracer detail: 0 off, 1 lifecycle events + "
                         "per-step phase records (default), 2 adds "
                         "per-chunk/per-decode-step events")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace_event JSON of "
                         "the run to PATH (batch mode: after the run; "
                         "--serve: on ctrl-c shutdown); load it in "
                         "ui.perfetto.dev or chrome://tracing")
    args = ap.parse_args()

    cfg = get_smoke(args.arch).with_(dtype="float32")
    session = Session(cfg)
    spec = None
    if args.spec_k > 0:
        from repro.serve.speculative import SpecConfig
        spec = SpecConfig(k=args.spec_k, drafter=args.drafter)
    serve_kw = dict(tp=args.tp, dp=args.dp,
                    slots=args.slots, max_len=args.max_len,
                    temperature=args.temperature,
                    paged=False if args.dense else None,
                    page_size=args.page_size, kv_pages=args.kv_pages,
                    prefix_cache=args.prefix_cache, lazy=args.lazy,
                    mixed=False if (args.no_mixed or args.dense) else None,
                    chunk_tokens=args.chunk_tokens,
                    attn_backend=args.attn_backend, spec=spec,
                    trace_level=args.trace_level)
    if args.serve:
        wt = args.watchdog_timeout if args.watchdog_timeout > 0 else None
        server = session.serve_http(host=args.host, port=args.port,
                                    watchdog_timeout=wt, **serve_kw)
        print(f"serving {args.arch} on {server.url} "
              f"(POST /generate, GET /metrics, GET /healthz, "
              f"GET /debug/flight, GET /debug/trace; "
              f"watchdog {'off' if wt is None else f'{wt}s'}) "
              f"— ctrl-c to stop", flush=True)
        try:
            while True:
                time.sleep(3600)
        except KeyboardInterrupt:
            if args.trace_out:
                server.driver.export_trace(args.trace_out)
                print(f"trace written to {args.trace_out}", flush=True)
            server.close(drain=False)
        return
    eng = session.serve(**serve_kw)

    rng = np.random.default_rng(0)
    system = rng.integers(0, cfg.vocab_size, size=(args.shared_prefix,))
    for rid in range(args.requests):
        n = int(rng.integers(4, 16))
        frames = (rng.standard_normal((cfg.encoder_ctx, cfg.d_model))
                  .astype(np.float32) if cfg.arch_type == "audio" else None)
        # VLM archs carry synthetic patch embeddings, standing in for
        # the stubbed ViT+projector frontend exactly as frames stand in
        # for the audio mel+conv stack
        images = (rng.standard_normal((cfg.num_image_tokens, cfg.d_model))
                  .astype(np.float32) if cfg.arch_type == "vlm" else None)
        prompt = np.concatenate(
            [system, rng.integers(0, cfg.vocab_size, size=(n,))])
        eng.submit(rid, prompt, max_new=args.max_new, frames=frames,
                   images=images)

    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in results.values())
    # a dp>1 serve() returns a ReplicaRouter; report its aggregate stats
    # and describe the layout from the first (representative) replica
    rep = eng.engines[0] if hasattr(eng, "engines") else eng
    st = eng.stats
    # trace counters are per-replica: report the worst engine so "1
    # decode trace/replica" states the invariant, not a dp-fold sum
    traces = max(r["decode_traces"] for r in st.get("replicas", [st]))
    layout = f"paged/{rep.page_size}tok-pages" if rep.paged else "dense"
    if getattr(rep, "attn_backend", "gather") != "gather":
        layout += f"+{rep.attn_backend}"
    par = f", tp{rep.tp}" + (f" x dp{eng.dp}" if hasattr(eng, "dp") else "")
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.2f}s ({total / dt:.1f} tok/s, {args.slots} slots{par}, "
          f"{layout} kv {eng.kv_bytes() / 1e6:.1f}MB global / "
          f"{eng.per_device_kv_bytes() / 1e6:.1f}MB per device, "
          f"{st['decode_steps']} decode calls, "
          f"{traces} decode trace/replica)")
    if rep.paged:
        pool = rep.kv_pages * (eng.dp if hasattr(eng, "dp") else 1)
        print(f"  pool: peak {st['peak_pages']}/{pool} pages, "
              f"prefix hit/miss {st['prefix_hit_blocks']}/"
              f"{st['prefix_miss_blocks']} blocks "
              f"(+{st['prefix_tail_hits']} tail), "
              f"{st['preemptions']} preemptions, "
              f"{st['cow_copies']} CoW copies, "
              f"{st['prefix_evictions']} evictions")
    if spec is not None:
        drafted = st.get("spec_drafted", 0)
        accepted = st.get("spec_accepted", 0)
        per_step = ((st["decode_tokens"] - st["prefills"])
                    / max(st.get("decode_slot_steps", 0), 1))
        print(f"  spec: k={args.spec_k} drafter={args.drafter}, "
              f"{accepted}/{drafted} drafts accepted "
              f"({accepted / max(drafted, 1):.2f}), "
              f"{per_step:.2f} accepted tokens/decode step")
    if args.trace_out:
        obj = eng.export_trace(args.trace_out)
        print(f"  trace: {len(obj['traceEvents'])} events written to "
              f"{args.trace_out} (load in ui.perfetto.dev)")
    for rid in sorted(results):
        r = results[rid]
        print(f"  req {rid}{'' if r.done else ' [truncated]'}: {r.out}")


if __name__ == "__main__":
    main()
