import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) on the production meshes, report
memory_analysis / cost_analysis / collective schedule, and emit the
roofline rows for EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--optimized]
"""
import argparse
import json
import traceback
from pathlib import Path

from repro.api import Session
from repro.configs import (ARCH_NAMES, SHAPES, get_config, long_500k_policy)
from repro.core.strategy import Strategy
from repro.launch.mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SWA_WINDOW = 4096


def effective_config(arch: str, shape_name: str):
    """Apply the long_500k policy (DESIGN.md §4): swa variant, run, or skip."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        pol = long_500k_policy(arch)
        if pol == "skip":
            return None, pol
        if pol in ("swa", "run") and cfg.num_heads > 0:
            # dense archs: SWA variant; hybrids: window on the shared block
            cfg = cfg.with_(sliding_window=SWA_WINDOW)
    return cfg, "ok"


def choose_strategy(cfg, shape, mesh, *, optimized: bool = False) -> Strategy:
    """Paper-faithful baseline: Megatron dp x tp (+ZeRO-1, remat, micro-
    batching — all used by the paper's case-studies). ``optimized`` layers
    on the beyond-paper knobs (sequence parallelism, FSDP, triangle attn)."""
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    st = Strategy(dtype=cfg.dtype)
    if shape.kind == "train":
        micro = max(1, shape.global_batch // dp) if shape.global_batch % dp == 0 else 1
        st = st.with_(microbatches=micro)
        # the 1T MoE cannot hold AdamW fp32 states even ZeRO-1-sharded:
        # planner switches it to adafactor+FSDP (recorded in EXPERIMENTS.md)
        if cfg.param_count() > 4e11:
            st = st.with_(optimizer="adafactor", fsdp=True)
    else:
        st = st.with_(remat=False, microbatches=1)
        # big-model inference: params must shard over data too
        if cfg.param_count() * 2 / mesh.shape.get("model", 1) > 8e9:
            st = st.with_(fsdp=True)
    if optimized:
        # triangle attention skips dead causal blocks but its dynamic-bound
        # inner loop is not reverse-differentiable -> inference only
        st = st.with_(seq_parallel=True,
                      attn_impl="auto" if shape.kind == "train"
                      else "triangle",
                      grad_accum_dtype="bfloat16",
                      name=st.name + "+opt")
        if shape.kind == "prefill" and shape.global_batch % (4 * dp) == 0:
            # batch-chunked prefill bounds the activation / MoE-dispatch
            # working set (§Perf kimi prefill iteration)
            st = st.with_(microbatches=4)
    return st


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              optimized: bool = False, mesh=None, strategy=None,
              verbose: bool = True):
    """Returns (record dict, compiled) or a skip record.

    Strategy selection + long_500k policy live here; the lower+compile+
    report machinery is ``repro.api.Session.lower`` (shared with every
    other execution mode)."""
    shape = SHAPES[shape_name]
    cfg, pol = effective_config(arch, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": f"skipped ({long_500k_policy(arch)} policy: "
                          "full-attention arch at 500k)"}, None
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = strategy or choose_strategy(cfg, shape, mesh,
                                           optimized=optimized)
    if optimized and shape.kind == "decode":
        # beyond-paper: context-parallel decode attention (see
        # models/cp_attention.py) for seq-sharded caches
        cfg = cfg.with_(cp_decode=True)
    session = Session(cfg, strategy, mesh)
    return session.lower(shape, verbose=verbose, arch=arch,
                         mesh_name=mesh_name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper strategy (SP + FSDP + triangle attn)")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    combos = ([(args.arch, args.shape)] if not args.all else
              [(a, s) for a in ARCH_NAMES for s in SHAPES])
    tag = "opt" if args.optimized else "base"
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    failures = []
    for arch, shape in combos:
        try:
            rec, _ = lower_one(arch, shape, multi_pod=args.multi_pod,
                               optimized=args.optimized, mesh=mesh)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": str(e)[:2000]}
            failures.append((arch, shape))
        mesh_name = rec.get("mesh", "pod2x16x16" if args.multi_pod
                            else "pod16x16")
        fn = out_dir / f"{arch}__{shape}__{mesh_name}__{tag}.json"
        fn.write_text(json.dumps(rec, indent=2, default=str))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete:", len(combos), "combos")


if __name__ == "__main__":
    main()
