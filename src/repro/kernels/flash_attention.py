"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the GPU flash algorithm (DESIGN.md §3): instead of a
warp-level softmax with shared-memory tiles, we tile HBM->VMEM with
BlockSpecs sized for the MXU (q/k blocks are multiples of 128 in the lane
dim) and carry the online-softmax state (m, l, acc) in VMEM scratch across
the *sequential* kv grid dimension. Causality is handled per-block: fully
masked blocks are skipped with ``pl.when`` (the compute saving the XLA
"masked" baseline cannot express).

Grid: (batch*heads, nq, nk) with nk innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, block_q: int, block_k: int,
            nk: int, sm_scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level relevance: skip fully-masked (future / out-of-window) blocks
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,S,H,D), k/v (B,T,H,D) MHA (pre-repeat GQA heads). -> (B,S,H,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    nq, nk = s // block_q, t // block_k

    # (B,S,H,D) -> (B*H, S, D) for a clean 3-D blocking
    qr = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * h, t, d)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, nk=nk, sm_scale=1.0 / np.sqrt(d))

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
