"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the GPU flash algorithm (DESIGN.md §3): instead of a
warp-level softmax with shared-memory tiles, we tile HBM->VMEM with
BlockSpecs sized for the MXU (q/k blocks are multiples of 128 in the lane
dim) and carry the online-softmax state (m, l, acc) in VMEM scratch across
the *sequential* kv grid dimension. Causality is handled per-block: fully
masked blocks are skipped with ``pl.when`` (the compute saving the XLA
"masked" baseline cannot express).

GQA runs natively: query-head program ``bh`` reads KV row
``q_head // rep`` through the BlockSpec index map, so grouped KV is never
repeated to Hq width in HBM. Ragged sequence lengths are padded up to the
block grid and the tail masked with the same kv-bound helper the paged
decode kernel (kernels/paged_attention.py) uses for its last page.

Grid: (batch*q_heads, nq, nk) with nk innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def tpu_compiler_params(**kw):
    """Compat shim: jax renamed ``TPUCompilerParams`` to
    ``CompilerParams`` across releases; kernels must load under both."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)


def pad_to_block(x, axis: int, block: int):
    """Zero-pad ``x`` along ``axis`` up to the next multiple of
    ``block`` (no-op when it already divides). The pad positions carry
    garbage logits downstream, so every consumer must mask them with
    :func:`kv_bound_mask` / slice them off the output."""
    n = x.shape[axis]
    extra = (-n) % block
    if extra == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, extra)
    return jnp.pad(x, widths)


def kv_bound_mask(kpos, kv_len):
    """True where a KV position is live: ``kpos < kv_len``. Shared
    between the flash kernel's ragged-tail masking (``kv_len`` = the
    real, pre-padding T) and the paged decode kernel's last-page /
    null-page masking (``kv_len = pos + 1``)."""
    return kpos < kv_len


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, block_q: int, block_k: int,
            nk: int, t_real: int, sm_scale: float):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level relevance: skip fully-masked (future / out-of-window /
    # ragged-pad) blocks
    relevant = k_start < t_real
    if causal:
        relevant = jnp.logical_and(relevant,
                                   k_start <= q_start + block_q - 1)
    if window > 0:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0].astype(jnp.float32)              # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kv_bound_mask(kpos, t_real)            # ragged pad tail
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,S,Hq,D), k/v (B,T,Hkv,D) -> (B,S,Hq,D).

    GQA (Hq a multiple of Hkv) maps each query head to its KV head in
    the kernel's index map — callers never pre-repeat. S/T need not
    divide the block sizes: ragged tails are padded to the grid and
    masked (kv) / sliced (q) away.
    """
    b, s, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError(
            f"query heads ({hq}) must be a multiple of KV heads ({hkv})")
    rep = hq // hkv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    q = pad_to_block(q, 1, block_q)
    k = pad_to_block(k, 1, block_k)
    v = pad_to_block(v, 1, block_k)
    sp, tp = q.shape[1], k.shape[1]
    nq, nk = sp // block_q, tp // block_k

    # (B,S,H,D) -> (B*H, S, D) for a clean 3-D blocking; KV keeps Hkv rows
    qr = q.transpose(0, 2, 1, 3).reshape(b * hq, sp, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * hkv, tp, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * hkv, tp, d)

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, nk=nk, t_real=t, sm_scale=1.0 / np.sqrt(d))

    # GQA head fold: query-head program bh = batch*hq + qh reads KV row
    # (batch, qh // rep) — same mapping as the paged decode kernel
    def _kv_row(bh):
        return (bh // hq) * hkv + (bh % hq) // rep

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki: (_kv_row(bh), ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki: (_kv_row(bh), ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sp, d).transpose(0, 2, 1, 3)[:, :s]
