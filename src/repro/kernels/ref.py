"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B,S,H,D), k/v (B,T,H,D) — MHA (callers pre-repeat GQA heads)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def fused_mlp_ref(x, w_gate, w_up, w_down):
    """SwiGLU MLP: (silu(x@Wg) * (x@Wu)) @ Wd, fp32 accumulation."""
    x32 = x.astype(jnp.float32)
    g = jax.nn.silu(x32 @ w_gate.astype(jnp.float32))
    u = x32 @ w_up.astype(jnp.float32)
    return ((g * u) @ w_down.astype(jnp.float32)).astype(x.dtype)


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int = 0):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x (b,s,h,p), dt (b,s,h), A (h,), B/C (b,s,n). Returns y (b,s,h,p).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(hstate, inp):
        xt, dtt, bt, ct = inp              # (b,h,p), (b,h), (b,n), (b,n)
        a = jnp.exp(dtt * A[None])         # (b,h)
        upd = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt.astype(jnp.float32),
                         xt.astype(jnp.float32))
        hstate = a[:, :, None, None] * hstate + upd
        y = jnp.einsum("bn,bhpn->bhp", ct.astype(jnp.float32), hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                                    B.swapaxes(0, 1), C.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype)
