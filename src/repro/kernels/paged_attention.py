"""Flash-decoding paged attention as a Pallas TPU kernel.

The gather path (models/layers.paged_attention with ``backend="gather"``)
materializes each row's ``P * page_size`` KV positions per layer through
XLA before attending — the memory-bound inefficiency hand-written decode
kernels exist to close. This kernel walks the page table directly: the
grid is one block PER PAGE with the online-softmax state (m, l, acc —
the same VMEM scratch pattern as kernels/flash_attention.py::_kernel)
carried across the sequential page axis, and the pool
``(n_pages, page_size, Hkv, D)`` is indexed through the per-row table by
a scalar-prefetched BlockSpec index map — contiguous KV never exists.

Contracts it shares with the gather path (the serve engine relies on
all three):

  * table VALUES are data, table SHAPE is static — one decode trace,
    retrace only when the engine's ``page_bucket`` width crosses;
  * ``kv_len = pos + 1`` masks everything behind each row's cursor, so
    null-page-0 entries (inactive slots, reservation tails, ragged last
    pages) contribute nothing — shared helper
    :func:`~repro.kernels.flash_attention.kv_bound_mask`;
  * GQA query heads map to their ``q_head // rep`` KV head in-kernel
    (never pre-repeated), and every program stays head-local, so the
    tp head-sharded pool (core/sharding.cache_pspecs) composes: each
    shard's kernel sees its own Hkv/tp heads.

Grid: (B*Hkv, P) with the page axis innermost/sequential; each program
handles all ``rep = Hq // Hkv`` query heads of one (row, kv-head) pair.
On CPU the wrapper runs with ``interpret=True`` (kernels/ops.py flips it
by backend), so CI exercises this exact code path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import (NEG_INF, kv_bound_mask,
                                           tpu_compiler_params)


def _kernel(tab_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, page_size: int, n_blocks: int,
            hkv: int, rep: int, sm_scale: float):
    """One (row, kv-head) pair x one page. ``tab_ref``/``pos_ref`` are
    the scalar-prefetched page table (B, P) and cursors (B,) — the same
    table also drives the K/V BlockSpec index maps, which is what makes
    the pool lookup a block fetch instead of a gather."""
    bh, p = pl.program_id(0), pl.program_id(1)
    row = bh // hkv

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = pos_ref[row] + 1                   # query attends [0, pos]

    # skip pages entirely past the row's live prefix (null-page tail of
    # the bucketed table included — their positions are all >= kv_len)
    @pl.when(p * page_size < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)     # (rep, d)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page_size, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (rep, ps)
        # page p covers positions [p*page_size, (p+1)*page_size): the
        # ragged last page masks exactly like the flash kernel's ragged
        # tail, via the shared kv-bound helper
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (rep, page_size), 1)
        s = jnp.where(kv_bound_mask(kpos, kv_len), s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p_exp = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p_exp, axis=1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p_exp.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))

    @pl.when(p == n_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, pos, *,
                    interpret: bool = False):
    """Fused paged-attention decode. Same contract as the gather path
    (models/layers.paged_attention):

    q: (B, 1, Hq, D) — one fresh token per row.
    k_pages/v_pages: (n_pages, page_size, Hkv, D) flat shared pool
    (page 0 is the null page).
    page_table: (B, P) pool indices in logical-block order.
    pos: (B,) per-row cursors (or scalar, broadcast) — the query
    attends to positions [0, pos].

    -> (B, 1, Hq, D), with no ``(B, P*page_size, ...)`` intermediate.
    """
    b, _, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    if hq % hkv:
        raise ValueError(
            f"query heads ({hq}) must be a multiple of KV heads ({hkv})")
    rep = hq // hkv
    n_blocks = page_table.shape[1]
    # group query heads by their KV head: consecutive q heads share one
    # kv head (the _repeat_kv layout), so this reshape IS the mapping
    qr = q.reshape(b, hkv, rep, d)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))

    kernel = functools.partial(
        _kernel, page_size=page_size, n_blocks=n_blocks, hkv=hkv,
        rep=rep, sm_scale=1.0 / np.sqrt(d))

    q_spec = pl.BlockSpec(
        (1, 1, rep, d),
        lambda bh, p, tab, pos_r: (bh // hkv, bh % hkv, 0, 0))
    # the tentpole line: the PAGE axis block index comes from the
    # prefetched table, so the pool block (1, page_size, 1, d) streams
    # straight from wherever the allocator put it
    kv_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda bh, p, tab, pos_r: (tab[bh // hkv, p], 0, bh % hkv, 0))

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b * hkv, n_blocks),
            in_specs=[q_spec, kv_spec, kv_spec],
            out_specs=pl.BlockSpec(
                (1, 1, rep, d),
                lambda bh, p, tab, pos_r: (bh // hkv, bh % hkv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((rep,), jnp.float32),        # running max m
                pltpu.VMEM((rep,), jnp.float32),        # running sum l
                pltpu.VMEM((rep, d), jnp.float32),      # accumulator
            ]),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rep, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos, qr, k_pages, v_pages)
    return out.reshape(b, 1, hq, d)
