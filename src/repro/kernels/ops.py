"""jit'd wrappers around the Pallas kernels — the public ops API.

On this CPU box the kernels run with interpret=True (Pallas executes the
kernel body in Python); on a real TPU the same calls compile to Mosaic.
``INTERPRET`` flips automatically based on the backend.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.fused_mlp import fused_mlp as _fused_mlp
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.ssd_scan import ssd_scan as _ssd_scan

INTERPRET = jax.default_backend() != "tpu"


from functools import partial

import jax.numpy as jnp
import numpy as np


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_trainable(q, k, v, causal, window, block_q, block_k):
    return _flash(q, k, v, causal=causal, window=window, block_q=block_q,
                  block_k=block_k, interpret=INTERPRET)


def _flash_fwd(q, k, v, causal, window, block_q, block_k):
    out = _flash_trainable(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, res, g):
    """Analytic backward via softmax recompute (pure jnp; on TPU this
    would be a second Pallas kernel — the math is identical). GQA: the
    recompute repeats KV to Hq width, then dk/dv group-sum back to Hkv —
    the transpose of the forward's in-kernel head fold."""
    q, k, v = res
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s32 = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                     k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s32 = jnp.where(mask[None, None], s32, -1e30)
    p = jax.nn.softmax(s32, axis=-1)                        # (B,H,S,T)
    g32 = g.astype(jnp.float32)
    dv = jnp.einsum("bhst,bshd->bthd", p, g32)
    dp = jnp.einsum("bshd,bthd->bhst", g32, v.astype(jnp.float32))
    dsoft = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dsoft = dsoft / np.sqrt(d)
    dq = jnp.einsum("bhst,bthd->bshd", dsoft, k.astype(jnp.float32))
    dk = jnp.einsum("bhst,bshd->bthd", dsoft, q.astype(jnp.float32))
    if rep > 1:
        dk = dk.reshape(b, t, hkv, rep, d).sum(axis=3)
        dv = dv.reshape(b, t, hkv, rep, d).sum(axis=3)
    return dq.astype(q.dtype), dk.astype(res[1].dtype), \
        dv.astype(res[2].dtype)


_flash_trainable.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """GQA-aware entry: query heads map to their KV head inside the
    kernel's index map (no HBM repeat — k/v stay Hkv wide end to end).
    Differentiable (custom VJP)."""
    return _flash_trainable(q, k, v, causal, window, block_q, block_k)


def paged_attention(q, k_pages, v_pages, page_table, pos):
    """Fused flash-decoding paged attention: walks the page table with
    online softmax across the page axis — never materializes the
    gathered ``(B, P*page_size, ...)`` KV (kernels/paged_attention.py).
    Decode-only (no VJP): the serve engine's per-step program."""
    return _paged(q, k_pages, v_pages, page_table, pos,
                  interpret=INTERPRET)


def fused_mlp(x, w_gate, w_up, w_down, *, block_m: int = 256,
              block_f: int = 512):
    orig = x.shape
    x2 = x.reshape(-1, orig[-1])
    y = _fused_mlp(x2, w_gate, w_up, w_down, block_m=block_m,
                   block_f=block_f, interpret=INTERPRET)
    return y.reshape(orig)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128):
    return _ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=INTERPRET)
