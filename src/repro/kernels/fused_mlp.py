"""Fused Megatron MLP as a Pallas TPU kernel.

The paper's §5.1 block — Z = (silu(X·Wg) ∘ (X·Wu))·Wd — fused so the
(t, d_ff) gated intermediate NEVER round-trips to HBM: for each (row-block,
ff-block) grid step we compute the gated partial in VMEM and immediately
accumulate its down-projection into the fp32 output scratch. HBM traffic
drops from 2·t·f (write+read the intermediate) to 0, which is exactly the
memory-roofline motivation for fusing the column-parallel branch.

Grid: (nm, nf) with nf sequential (accumulation); blocks are MXU-aligned
(multiples of 128 in the lane dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nf: int):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, d)
    g = jax.lax.dot(x, wg_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)      # (bm, bf)
    u = jax.lax.dot(x, wu_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
    h = (g * jax.lax.logistic(g)) * u             # silu(g) * u
    acc_ref[...] += jax.lax.dot(h.astype(wd_ref.dtype),
                                wd_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_f",
                                             "interpret"))
def fused_mlp(x, w_gate, w_up, w_down, *, block_m: int = 256,
              block_f: int = 512, interpret: bool = False):
    """x (T, d); w_gate/w_up (d, f); w_down (f, d) -> (T, d)."""
    t, d = x.shape
    f = w_gate.shape[1]
    block_m = min(block_m, t)
    block_f = min(block_f, f)
    assert t % block_m == 0 and f % block_f == 0, (t, f, block_m, block_f)
    nm, nf = t // block_m, f // block_f

    return pl.pallas_call(
        functools.partial(_kernel, nf=nf),
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)),
            pl.BlockSpec((block_f, d), lambda mi, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
