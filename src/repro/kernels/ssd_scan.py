"""Mamba2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD algorithm [arXiv:2405.21060] (DESIGN.md §3): one
grid step per (batch*head, chunk); the chunk dimension is sequential and the
running state (P x N, fp32) lives in VMEM scratch — the TPU analogue of the
paper's inter-chunk recurrence held in registers/SMEM on GPU. Per chunk:

  intra:  Y += (tril(C Bᵀ) ∘ decay) · (dt∘X)        (MXU matmuls, Q x Q)
  inter:  Y += (C · h) ∘ exp(cum)                   (state from prev chunks)
  state:  h  = exp(cum_last)·h + Σ_j exp(cum_last - cum_j) B_j (dt x)_j

Single B/C group (G=1) as in the released models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, h_ref, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)              # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)            # (Q,)
    a = a_ref[0, 0]                               # scalar A_h (negative)
    bmat = b_ref[0].astype(jnp.float32)           # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)           # (Q, N)

    la = dt * a                                   # (Q,) log-decay per step
    cum = jnp.cumsum(la)                          # (Q,) decay to t
    xdt = x * dt[:, None]

    # intra-chunk: scores (Q,Q) on the MXU, masked decay applied
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    dec = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = jax.lax.dot(scores * dec, xdt,
                    preferred_element_type=jnp.float32)      # (Q, P)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot(
        cmat, h, preferred_element_type=jnp.float32)

    # state update: h' = exp(cum_last) h + Σ_j exp(cum_last-cum_j) B_j xdt_j
    wj = jnp.exp(cum[-1] - cum)                   # (Q,)
    h_ref[...] = (jnp.exp(cum[-1]) * h
                  + jax.lax.dot_general(bmat * wj[:, None], xdt,
                                        (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = False):
    """x (b,s,h,p), dt (b,s,h) fp32, A (h,), B/C (b,s,n) -> y (b,s,h,p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, s)
    assert s % q == 0
    nc = s // q

    # (BH, nc... ) layout: head-major so each grid row owns one (batch, head)
    xr = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtr = dt.transpose(0, 2, 1).reshape(b * h, s)
    ar = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)
    br = jnp.broadcast_to(B[:, None], (b, h, s, n)).reshape(b * h, s, n)
    cr = jnp.broadcast_to(C[:, None], (b, h, s, n)).reshape(b * h, s, n)

    out = pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, q), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, q, n), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, q, n), lambda bh, ci: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)
