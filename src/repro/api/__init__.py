"""repro.api — the public surface of the repro system.

Close the survey's §4 auto-parallelisation loop in three calls:

    from repro.api import Session, plan

    p = plan(cfg, shape, chips=jax.device_count())   # search  (§4)
    print(p.summary())                               # inspect
    session = Session.from_plan(cfg, p)              # execute: plan ->
    session.train(...) / .generate / .serve / .dryrun    # one facade

Everything here is re-exported from the subsystem modules so callers
depend on ONE import path; the subsystem modules stay importable for
backwards compatibility.
"""
from repro.core.costmodel import Degrees, Hardware, V5E  # noqa: F401
from repro.core.planner import Plan, plan  # noqa: F401
from repro.core.strategy import MEGATRON_BASELINE, MEGATRON_SP, Strategy  # noqa: F401
from repro.launch.mesh import (make_host_mesh, make_mesh,  # noqa: F401
                               make_pipeline_mesh, make_production_mesh)
from repro.train.trainer import TrainConfig, Trainer  # noqa: F401
from repro.serve.driver import AsyncDriver, TokenStream  # noqa: F401
from repro.serve.metrics import ServeMetrics  # noqa: F401
from repro.serve.server import ServeHTTPServer  # noqa: F401
from repro.api.session import Session  # noqa: F401

__all__ = [
    "Session", "Plan", "plan", "Strategy", "Degrees", "Hardware", "V5E",
    "MEGATRON_BASELINE", "MEGATRON_SP", "TrainConfig", "Trainer",
    "AsyncDriver", "TokenStream", "ServeMetrics", "ServeHTTPServer",
    "make_mesh", "make_host_mesh", "make_pipeline_mesh",
    "make_production_mesh",
]
