"""Session — the unified execution facade.

The survey's §4 loop is: partition the operator graph, evaluate
strategies, execute the winner. ``repro.core.planner.plan`` does the first
two; a :class:`Session` does the third. One object owns the
``(config, strategy, mesh)`` triple plus the params, and exposes every
execution mode behind it:

    from repro.api import Session, plan

    p = plan(cfg, shape, chips=jax.device_count())
    session = Session.from_plan(cfg, p)          # plan -> (Strategy, Mesh)
    trainer = session.train(TrainConfig(steps=100))
    trainer.run()
    tokens = session.generate(prompt_tokens, steps=16)   # trained params
    engine = session.serve(slots=4, max_len=256)
    driver = session.serve_async(watchdog_timeout=30.0)  # online streaming
    server = session.serve_http(port=8000)       # POST /generate, /metrics
    record = session.dryrun("train_4k")          # lower+compile, no alloc

Params thread through: ``generate``/``serve`` after ``train`` see the
trained weights; ``restore``/``save`` give the Session checkpoint
ownership so callers never juggle param trees themselves.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES
from repro.core.planner import Plan
from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.launch.mesh import make_host_mesh
from repro.serve.engine import ServeEngine
from repro.serve.step import greedy_generate
from repro.train.trainer import (TrainConfig, Trainer, init_sharded_params)

ShapeLike = Union[str, ShapeConfig]


class Session:
    """One (config, strategy, mesh) triple, every execution mode."""

    def __init__(self, cfg: ModelConfig, strategy: Optional[Strategy] = None,
                 mesh=None, *, params=None, seed: int = 0):
        self.cfg = cfg
        self.strategy = strategy if strategy is not None else \
            Strategy(dtype=cfg.dtype)
        self.mesh = mesh if mesh is not None else make_host_mesh(model=1)
        self.seed = seed
        self.plan: Optional[Plan] = None     # set by from_plan
        self._params = params
        self._trainer: Optional[Trainer] = None

    @classmethod
    def from_plan(cls, cfg: ModelConfig, plan: Plan, *,
                  devices: Union[None, int, list] = None, seed: int = 0,
                  **strategy_overrides) -> "Session":
        """Materialize a planner Plan and build the Session on it — the
        search-to-execution hand-off (GSPMD/Alpa shape). Strategy-field
        overrides (``dtype="float32"``, ``remat=False``, ...) pass
        through to :meth:`Plan.materialize`. The plan is kept on the
        session, so a later :meth:`serve` defaults to ITS tp/dp degrees
        — ``Session.from_plan(cfg, plan(...)).serve()`` serves sharded
        on exactly the topology the planner chose."""
        strategy, mesh = plan.materialize(devices=devices,
                                          **strategy_overrides)
        session = cls(cfg, strategy, mesh, seed=seed)
        session.plan = plan
        return session

    # ------------------------------------------------------------- params
    @property
    def params(self):
        """Current param tree. Lazily initialised (sharded onto the mesh);
        after ``train`` this is the TRAINED tree, not the init one."""
        if self._trainer is not None:
            self._params = self._trainer.params
        elif self._params is None:
            self._params = init_sharded_params(self.cfg, self.strategy,
                                               self.mesh, seed=self.seed)
        return self._params

    @params.setter
    def params(self, value):
        self._trainer = None
        self._params = value

    def restore(self, checkpoint_dir: str) -> Optional[int]:
        """Load the latest checkpoint under ``checkpoint_dir`` into the
        session (None if there is none). Returns the restored step."""
        last = latest_step(checkpoint_dir)
        if last is not None:
            self.params = load_checkpoint(checkpoint_dir, last, self.params)
        return last

    def save(self, checkpoint_dir: str, step: int = 0):
        return save_checkpoint(checkpoint_dir, step, self.params)

    # -------------------------------------------------------------- train
    def train(self, train_cfg: Optional[TrainConfig] = None, *,
              data=None, global_batch: int = 8, seq_len: int = 256,
              restore: bool = False) -> Trainer:
        """Build a Trainer on this session's strategy/mesh/params.

        The returned Trainer is live-linked: once created, ``session
        .params`` tracks its (donated-and-updated) param tree, so a
        subsequent ``generate``/``serve``/``save`` uses the trained
        weights. ``restore=True`` resumes from the TrainConfig's
        checkpoint dir first."""
        tc = train_cfg or TrainConfig(seed=self.seed)
        if self._trainer is not None:
            # adopt the previous trainer's (trained) tree so back-to-back
            # train() calls continue rather than restart
            self._params = self._trainer.params
            self._trainer = None
        # materialize via the property so param init always uses the
        # SESSION's seed (not the TrainConfig's), independent of whether
        # .params was read before train()
        trainer = Trainer(self.cfg, self.strategy, self.mesh, tc, data=data,
                          global_batch=global_batch, seq_len=seq_len,
                          params=self.params)
        self._trainer = trainer
        if restore:
            trainer.maybe_restore()
        return trainer

    # ----------------------------------------------------------- generate
    def generate(self, prompt, steps: int = 16):
        """Greedy-decode ``steps`` tokens. ``prompt`` is a (b, s) or (s,)
        int array of token ids, or a full model batch dict."""
        if isinstance(prompt, dict):
            batch = prompt
        else:
            arr = jnp.asarray(np.asarray(prompt), jnp.int32)
            if arr.ndim == 1:
                arr = arr[None, :]
            batch = {"tokens": arr}
        return greedy_generate(self.params, self.cfg, self.strategy, batch,
                               steps=steps)

    # -------------------------------------------------------------- serve
    def serve(self, *, plan: Optional[Plan] = None, tp: Optional[int] = None,
              dp: Optional[int] = None, slots: int = 4, max_len: int = 256,
              eos_id: Optional[int] = None, temperature: float = 0.0,
              seed: Optional[int] = None, paged: Optional[bool] = None,
              page_size: int = 16, kv_pages: Optional[int] = None,
              prefix_cache: bool = False, lazy: bool = False,
              scheduler=None, mixed: Optional[bool] = None,
              chunk_tokens: int = 256, attn_backend: str = "gather",
              spec=None, trace_level: int = 1):
        """Continuous-batching engine over this session's params: one
        batched jitted decode advances the whole slot table per step.
        ``temperature > 0`` switches the on-device sampler from greedy to
        temperature sampling (seeded from the session seed by default).

        Parallel serving (the survey's intra-operator + replication
        split, serve/parallel.py): ``tp > 1`` runs ONE engine whose
        prefill/decode programs are GSPMD-sharded over a ("data",
        "model") mesh — Megatron param layout, head-sharded paged KV
        pool, still exactly one decode trace; ``dp > 1`` returns a
        :class:`~repro.serve.parallel.ReplicaRouter` of ``dp`` such
        engines over disjoint device slices, routed least-load with
        prefix-cache affinity. Defaults come from ``plan`` (an explicit
        Plan argument, else the session's own plan when it was built by
        :meth:`from_plan`), so ``Session.from_plan(cfg, plan(...))
        .serve()`` just works; explicit ``tp=`` / ``dp=`` override the
        plan, and a plain ``Session(cfg).serve()`` stays the familiar
        single unsharded engine. Pipeline degrees don't apply to the
        decode loop — a plan with ``pp > 1`` is rejected unless both
        overrides are given.

        KV layout: ``paged=None`` (default) picks the paged block-table
        cache for full-attention decoders (dense / MoE / enc-dec) and
        falls back to dense rows for SWA-ring and SSM archs;
        ``paged=False`` forces dense. Paged decode is token-identical to
        dense for row-independent archs; batched MoE is the standing
        exception — capacity routing couples slot rows (see the engine
        docstring), and inactive-row scratch differs between layouts, so
        multi-slot MoE outputs may differ across layouts as they already
        do across occupancies. ``page_size`` tokens per page;
        ``kv_pages`` bounds the shared pool (default: capacity parity
        with dense, ``slots * ceil(max_len / page_size)``) — size it below
        that to trade worst-case admission for HBM.

        Multi-tenant pool features (paged layout, all off by default):
        ``prefix_cache=True`` shares one physical copy of a common prompt
        prefix across requests via refcounted pages (exact — see the
        engine docstring for the MoE/enc-dec keying); ``lazy=True``
        reserves only the pages covering the prompt plus its first
        decode write at admission and grows on
        page-boundary crossings, preempting-and-requeuing the
        least-progress slot when the pool runs dry (greedy outputs stay
        bit-identical); ``scheduler`` overrides the admission/preemption
        policy (default: FIFO + least-progress-preempt,
        serve/scheduler.py; ``serve.scheduler.Priority`` honors
        ``submit(..., priority=)``).

        Mixed stepping: on the paged layout the engine defaults to the
        unified token-slot step (``mixed=None`` -> on) — every step runs
        ONE program over a ``chunk_tokens`` token budget shared between
        all decoding slots and the prefill CHUNKS of newly admitted
        requests, so long prompts no longer stall decode and prefill
        traces collapse into the single mixed program.
        ``mixed=False`` restores the legacy split prefill/decode path
        (bit-identical greedy outputs either way); ``chunk_tokens``
        (default 256, must be >= ``slots``) caps the per-step token
        count and thereby the worst-case step latency.

        Decode backend: ``attn_backend="pallas"`` switches the paged
        decode attention from the XLA gather path to the fused
        flash-decoding Pallas kernel (kernels/paged_attention.py — the
        page table drives the pool lookup in-kernel, so gathered KV is
        never materialized). Greedy outputs are token-identical, the
        one-trace-per-bucket cadence is unchanged, and it composes with
        ``tp`` (head-sharded pool stays head-local per device); on CPU
        the kernel runs in interpret mode. Requires the paged layout.

        Speculative decode: ``spec=SpecConfig(k=4, drafter="ngram")``
        (serve/speculative.py) packs up to ``k`` self-drafted tokens per
        decoding slot as extra rows of the mixed step, verifies them in
        the same single dispatch and accepts the longest greedy-matching
        prefix plus one bonus token — up to ``k + 1`` tokens per step
        for one program launch, bit-identical greedy output. Requires
        the mixed step, greedy sampling (``temperature == 0``) and
        ``chunk_tokens >= slots * (k + 1)``; composes with
        prefix+lazy sharing, both attn backends and ``tp``/``dp``.

        Observability: ``trace_level`` gates the engine's built-in
        tracer (serve/tracing.py) — 0 off, 1 (default) request lifecycle
        events + per-step phase records at O(1) cost, 2 adds per-chunk /
        per-decode-step detail events. ``engine.export_trace(path)``
        (router: merged across replicas) writes a Chrome/Perfetto
        ``trace_event`` JSON of the run."""
        p = plan if plan is not None else self.plan
        if tp is None or dp is None:
            if p is not None and p.degrees.pp > 1:
                raise ValueError(
                    f"plan[{p.method}] has pp={p.degrees.pp}: pipeline "
                    "parallelism has no serving path (decode is one "
                    "token deep) — re-plan with pp=1 or pass explicit "
                    "tp=/dp= to serve()")
            tp = (p.degrees.tp if p is not None else 1) if tp is None else tp
            dp = (p.degrees.dp if p is not None else 1) if dp is None else dp
        kw = dict(slots=slots, max_len=max_len, eos_id=eos_id,
                  temperature=temperature,
                  seed=self.seed if seed is None else seed,
                  paged=paged, page_size=page_size, kv_pages=kv_pages,
                  prefix_cache=prefix_cache, lazy=lazy, scheduler=scheduler,
                  mixed=mixed, chunk_tokens=chunk_tokens,
                  attn_backend=attn_backend, spec=spec,
                  trace_level=trace_level)
        if tp == 1 and dp == 1:
            return ServeEngine(self.cfg, self.params, **kw)
        # serve on the session's own device placement when its mesh IS the
        # dp x tp layout (the from_plan case); else the first dp*tp devices
        devices = None
        if tuple(self.mesh.axis_names) == ("data", "model") and \
                (self.mesh.shape["data"], self.mesh.shape["model"]) \
                == (dp, tp):
            devices = self.mesh.devices
        if dp == 1:
            from repro.serve.parallel import replica_meshes
            [mesh] = replica_meshes(1, tp, devices)
            return ServeEngine(self.cfg, self.params, mesh=mesh,
                               strategy=self.strategy, **kw)
        from repro.serve.parallel import ReplicaRouter
        return ReplicaRouter(self.cfg, self.params, dp=dp, tp=tp,
                             devices=devices, strategy=self.strategy, **kw)

    # ------------------------------------------------------- online serving
    def serve_async(self, *, watchdog_timeout: Optional[float] = None,
                    metrics=None, start: bool = True, **serve_kw):
        """ONLINE serving: :meth:`serve`'s engine (or ReplicaRouter —
        every ``serve`` kwarg passes through, plan-awareness included)
        wrapped in a :class:`~repro.serve.driver.AsyncDriver` — the step
        loop runs on a background thread, ``submit()`` accepts requests
        at any time and returns a per-request TokenStream, TTFT/TPOT/
        step latencies land in ``driver.metrics``, and
        ``watchdog_timeout`` arms stalled-step detection with
        cancel-and-requeue recovery. ``start=False`` defers the loop so
        a batch of submissions admits exactly like ``run()`` (the parity
        and bench path)."""
        from repro.serve.driver import AsyncDriver
        return AsyncDriver(self.serve(**serve_kw),
                           watchdog_timeout=watchdog_timeout,
                           metrics=metrics, start=start)

    def serve_http(self, *, host: str = "127.0.0.1", port: int = 0,
                   watchdog_timeout: Optional[float] = None,
                   **serve_kw):
        """:meth:`serve_async` behind the stdlib HTTP front-end
        (serve/server.py): ``POST /generate`` (optionally chunked token
        streaming), ``GET /metrics`` (Prometheus text), ``GET /healthz``.
        ``port=0`` binds a free port — read it back from ``.port``. The
        returned server owns its driver; ``close()`` drains and stops
        both."""
        from repro.serve.server import serve_http
        return serve_http(self.serve(**serve_kw), host=host, port=port,
                          watchdog_timeout=watchdog_timeout)

    # ------------------------------------------------------------- dryrun
    def dryrun(self, shape: ShapeLike, *, verbose: bool = False,
               arch: Optional[str] = None, mesh_name: Optional[str] = None
               ) -> Dict[str, Any]:
        """Lower + compile the step for ``shape`` on this session's mesh
        WITHOUT allocating params, and report memory/roofline analysis —
        the production what-if check behind ``launch/dryrun.py``."""
        rec, _ = self.lower(shape, verbose=verbose, arch=arch,
                            mesh_name=mesh_name)
        return rec

    def lower(self, shape: ShapeLike, *, verbose: bool = False,
              arch: Optional[str] = None, mesh_name: Optional[str] = None):
        """Like :meth:`dryrun` but also returns the compiled executable."""
        import time

        from repro.launch import roofline as rl
        from repro.launch import specs as sp
        from repro.serve.step import make_decode_step, make_prefill_step
        from repro.train.step import make_train_step

        shape = SHAPES[shape] if isinstance(shape, str) else shape
        cfg, strategy, mesh = self.cfg, self.strategy, self.mesh
        arch = arch or cfg.name
        mesh_name = mesh_name or "x".join(
            f"{mesh.shape[a]}{a}" for a in mesh.axis_names)
        chips = mesh.size
        t0 = time.time()

        with sharding_rules(mesh, strategy.rules(mesh)):
            if shape.kind == "train":
                step = make_train_step(cfg, strategy)
                args, in_sh = sp.train_specs(cfg, shape, mesh, strategy)
                jitted = jax.jit(step, in_shardings=in_sh,
                                 out_shardings=(in_sh[0], in_sh[1], None),
                                 donate_argnums=(0, 1))
                mf = rl.model_flops_train(cfg,
                                          shape.global_batch * shape.seq_len)
            elif shape.kind == "prefill":
                step = make_prefill_step(cfg, strategy)
                args, in_sh = sp.prefill_specs(cfg, shape, mesh, strategy)
                jitted = jax.jit(step, in_shardings=in_sh)
                mf = rl.model_flops_decode(cfg,
                                           shape.global_batch * shape.seq_len)
            else:  # decode: ONE token against a seq_len cache
                step = make_decode_step(cfg, strategy)
                args, in_sh = sp.decode_specs(cfg, shape, mesh, strategy)
                jitted = jax.jit(step, in_shardings=in_sh,
                                 donate_argnums=(1,))
                mf = rl.model_flops_decode(cfg, shape.global_batch)
            with mesh:
                lowered = jitted.lower(*args)
                compiled = lowered.compile()

        roof = rl.extract(compiled, arch=arch, shape=shape.name,
                          mesh_name=mesh_name, chips=chips, model_flops=mf)
        mem = compiled.memory_analysis()
        rec = {
            "arch": arch, "shape": shape.name, "mesh": mesh_name,
            "status": "ok", "strategy": strategy.name,
            "strategy_detail": {
                "seq_parallel": strategy.seq_parallel,
                "fsdp": strategy.fsdp,
                "optimizer": strategy.optimizer,
                "microbatches": strategy.microbatches,
                "remat": strategy.remat, "attn_impl": strategy.attn_impl},
            "compile_s": round(time.time() - t0, 1),
            "memory_analysis": {
                k: getattr(mem, k, None) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")},
            "roofline": roof.row(),
        }
        if verbose:
            r = roof.row()
            print(f"[{arch} x {shape.name} x {mesh_name}] compile "
                  f"{rec['compile_s']}s  bottleneck={r['bottleneck']} "
                  f"t_comp={r['t_compute_s']:.3e} t_mem={r['t_memory_s']:.3e} "
                  f"t_coll={r['t_collective_s']:.3e} "
                  f"useful={r['useful_ratio']:.2f} "
                  f"mem/dev={r['mem_per_device_gb']:.2f}GB", flush=True)
        return rec, compiled
