"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_activation_memory, bench_kernels,
                            bench_mfu_table1, bench_pipeline_bubble,
                            bench_roofline, bench_serve_throughput,
                            bench_table2_strategies, bench_table3_search)
    modules = [
        ("table1_mfu", bench_mfu_table1),
        ("table2_strategies", bench_table2_strategies),
        ("table3_search", bench_table3_search),
        ("fig5_pipeline_bubble", bench_pipeline_bubble),
        ("korthikanti_activation_memory", bench_activation_memory),
        ("kernels", bench_kernels),
        ("roofline", bench_roofline),
        ("serve_throughput", bench_serve_throughput),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for row in mod.run():
                derived = str(row["derived"]).replace(",", ";")
                print(f"{row['name']},{row['us_per_call']},{derived}")
        except Exception:
            traceback.print_exc()
            failures += 1
            print(f"{name},0,FAILED")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
