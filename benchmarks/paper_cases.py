"""The paper's case-study models (Tables 1-2) as configs + reported numbers.

These drive bench_mfu_table1 / bench_table2_strategies: we re-predict each
system's utilisation with our analytical cost model and compare against the
published figure — the survey's own data is the validation target.
"""
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.costmodel import A100, Degrees, TPU_V3, TPU_V4, V100

# dense GPT-family configs (public numbers)
GPT3_175B = ModelConfig(name="gpt3-175b", arch_type="dense", num_layers=96,
                        d_model=12288, num_heads=96, num_kv_heads=96,
                        d_ff=4 * 12288, vocab_size=50257)
GOPHER_280B = ModelConfig(name="gopher-280b", arch_type="dense",
                          num_layers=80, d_model=16384, num_heads=128,
                          num_kv_heads=128, d_ff=4 * 16384,
                          vocab_size=32000)
MT_NLG_530B = ModelConfig(name="mt-nlg-530b", arch_type="dense",
                          num_layers=105, d_model=20480, num_heads=128,
                          num_kv_heads=128, d_ff=4 * 20480,
                          vocab_size=50257)
PALM_540B = ModelConfig(name="palm-540b", arch_type="dense", num_layers=118,
                        d_model=18432, num_heads=48, num_kv_heads=1,
                        d_ff=4 * 18432, vocab_size=256000)
MEGATRON_8B = ModelConfig(name="megatron-8.3b", arch_type="dense",
                          num_layers=72, d_model=3072, num_heads=32,
                          num_kv_heads=32, d_ff=4 * 3072, vocab_size=50257)
MEGATRON_1T = ModelConfig(name="megatron-1t", arch_type="dense",
                          num_layers=128, d_model=25600, num_heads=160,
                          num_kv_heads=160, d_ff=4 * 25600,
                          vocab_size=50257)

# Table 1 rows: (config, hardware, chips, degrees, batch, seq, reported MFU%)
TABLE1 = [
    ("GPT-3", GPT3_175B, V100, 10000,
     Degrees(dp=1250, tp=8, pp=1, microbatches=8), 1536, 2048, 21.3),
    ("Gopher", GOPHER_280B, TPU_V3, 4096,
     Degrees(dp=512, tp=2, pp=4, microbatches=8), 2048, 2048, 32.5),
    ("Megatron-Turing", MT_NLG_530B, A100, 2240,
     Degrees(dp=8, tp=8, pp=35, microbatches=32), 1920, 2048, 30.2),
    ("PaLM", PALM_540B, TPU_V4, 6144,
     Degrees(dp=512, tp=12, pp=1, microbatches=4), 2048, 2048, 46.2),
]

# Table 2 rows: Megatron-family ad hoc strategies
TABLE2 = [
    ("Shoeybi'20 [28]", MEGATRON_8B, A100, Degrees(dp=8, tp=8, pp=1,
                                                   microbatches=4),
     512, 1024, None),          # paper reports <30% hardware util
    ("Narayanan'21 [21]", MEGATRON_1T, A100,
     Degrees(dp=6, tp=8, pp=64, microbatches=128), 3072, 2048, 52.0),
    ("Smith'22 [29]", MT_NLG_530B, A100,
     Degrees(dp=12, tp=8, pp=35, microbatches=32), 1920, 2048, 36.2),
    ("Korthikanti'23 [14]", MEGATRON_1T, A100,
     Degrees(dp=1, tp=8, pp=64, microbatches=128, seq_parallel=True),
     512, 2048, 56.3),
]
