"""Paper Fig. 5c/5d: the pipeline bubble and its reduction by micro-batching.
Two parts: (a) the schedule simulator vs the closed form (p-1)/(m+p-1);
(b) the REAL shard_map GPipe pipeline on a 4-stage CPU mesh — measured
wall time vs microbatch count must show the bubble amortising."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.pipeline import pipeline_apply, simulate_schedule
from repro.launch.mesh import make_mesh


def run() -> list:
    rows = []
    for p, m in [(4, 1), (4, 4), (4, 16), (4, 64), (8, 8), (8, 64)]:
        sim = simulate_schedule(p, m, schedule="gpipe")
        closed = (p - 1) / (m + p - 1)
        rows.append({
            "name": f"fig5/sim_p{p}_m{m}",
            "us_per_call": 0.0,
            "derived": (f"bubble={sim['bubble_fraction']:.4f} "
                        f"closed_form={closed:.4f} "
                        f"match={abs(sim['bubble_fraction'] - closed) < 1e-9}"),
        })

    # real pipeline wall time (CPU, 4 fake devices on the pipe axis)
    if len(jax.devices()) >= 4:
        mesh = make_mesh((1, 4, 1), ("data", "pipe", "model"))
        d, mb, stages = 256, 4, 4
        w = jax.random.normal(jax.random.key(0), (stages, d, d)) * 0.1

        def stage_fn(pw, xx):
            for _ in range(4):
                xx = jnp.tanh(xx @ pw)
            return xx

        for m in (1, 4, 16):
            x = jax.random.normal(jax.random.key(1), (m * mb, d))
            f = jax.jit(lambda w, x: pipeline_apply(
                stage_fn, w, x, mesh=mesh, num_microbatches=m))
            f(w, x).block_until_ready()
            t0 = time.perf_counter_ns()
            for _ in range(3):
                f(w, x).block_until_ready()
            us = (time.perf_counter_ns() - t0) / 3e3
            # per-token time should DROP with m (bubble amortised)
            rows.append({
                "name": f"fig5/shardmap_gpipe_m{m}",
                "us_per_call": round(us, 1),
                "derived": f"us_per_microbatch={us / m:.1f}",
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
