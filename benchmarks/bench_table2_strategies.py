"""Paper Table 2: the Megatron-family ad hoc strategies. For each published
(t, p, d) configuration we evaluate our cost model and check the paper's
three key takeaways from Narayanan et al. [21]:

  1. tensor parallelism up to the node size (t=8 on DGX), pipeline beyond;
  2. microbatch count trades bubble against per-microbatch efficiency;
  3. t*p chosen so the model fits, d used to scale out.
"""
from __future__ import annotations

import time

from repro.api import Degrees, Plan
from repro.configs.base import ShapeConfig
from repro.core.costmodel import A100
from benchmarks.paper_cases import TABLE2, MEGATRON_1T


def _case(cfg, shape, deg, hw) -> Plan:
    """Published (t, p, d) table rows as executable Plans — the same object
    the planner's search emits, so summary/row/materialize all apply."""
    return Plan.from_degrees(cfg, shape, deg, hw, method="table2")


def run() -> list:
    rows = []
    for name, cfg, hw, deg, batch, seq, reported in TABLE2:
        t0 = time.perf_counter_ns()
        shape = ShapeConfig("case", seq, batch, "train")
        p = _case(cfg, shape, deg, hw)
        us = (time.perf_counter_ns() - t0) / 1e3
        rep = f" reported={reported}%" if reported else ""
        rows.append({
            "name": f"table2/{name.split(' ')[0]}",
            "us_per_call": round(us, 1),
            "derived": (f"{p.summary(compact=True)} "
                        f"pred_mfu={p.mfu * 100:.1f}%{rep} "
                        f"bubble={p.breakdown.bubble_fraction:.3f} "
                        f"fits={p.fits}"),
        })

    # takeaway 1: for the 1T model, t=8 (node) beats t=64 (cross-node) at
    # equal chip count when pipeline takes the rest
    shape = ShapeConfig("case", 2048, 3072, "train")
    t8 = _case(MEGATRON_1T, shape,
               Degrees(dp=6, tp=8, pp=64, microbatches=32), A100)
    t64 = _case(MEGATRON_1T, shape,
                Degrees(dp=6, tp=64, pp=8, microbatches=32), A100)
    rows.append({"name": "table2/takeaway1_tp_in_node",
                 "us_per_call": 0.0,
                 "derived": (f"t8_step={t8.cost:.2f}s "
                             f"t64_step={t64.cost:.2f}s "
                             f"holds={t8.cost < t64.cost}")})
    # takeaway 2: more microbatches shrink the bubble monotonically
    bs = [_case(MEGATRON_1T, shape,
                Degrees(dp=6, tp=8, pp=64, microbatches=m),
                A100).breakdown.bubble_fraction for m in (8, 16, 32, 64)]
    rows.append({"name": "table2/takeaway2_microbatch_bubble",
                 "us_per_call": 0.0,
                 "derived": f"bubbles={[round(b, 3) for b in bs]} "
                            f"monotone={all(a > b for a, b in zip(bs, bs[1:]))}"})
    # takeaway 3: t*p must make the model fit; d alone does not help memory
    small_mp = _case(MEGATRON_1T, shape,
                     Degrees(dp=384, tp=8, pp=1, microbatches=8), A100)
    big_mp = _case(MEGATRON_1T, shape,
                   Degrees(dp=6, tp=8, pp=64, microbatches=32), A100)
    rows.append({"name": "table2/takeaway3_mp_for_memory",
                 "us_per_call": 0.0,
                 "derived": (f"tp8pp1_fits={small_mp.fits} "
                             f"tp8pp64_fits={big_mp.fits} "
                             f"holds={(not small_mp.fits) and big_mp.fits}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
