"""Kernel micro-benchmarks.

On this CPU box the Pallas kernels run in interpret mode (Python — timing
them is meaningless), so we report: (a) wall time of the XLA reference path
that the kernel replaces, (b) the kernel's STATIC roofline numbers per grid
step (VMEM working set, MXU FLOPs, HBM bytes saved by fusion) derived from
its BlockSpecs — the quantities that determine TPU performance."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _time(f, *args, reps=3):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.perf_counter_ns()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter_ns() - t0) / reps / 1e3


def run() -> list:
    rows = []
    key = jax.random.key(0)

    # flash attention: XLA ref wall time + kernel static analysis
    for s, d, bq, bk in [(1024, 64, 128, 128), (2048, 128, 128, 128)]:
        q = jax.random.normal(key, (1, s, 4, d), jnp.bfloat16)
        f = jax.jit(lambda q: ref.flash_attention_ref(q, q, q, causal=True))
        us = _time(f, q)
        vmem = (bq * d + 2 * bk * d) * 2 + bq * d * 4 + 2 * bq * 4
        flops_blk = 2 * bq * bk * d * 2
        rows.append({
            "name": f"kernel/flash_s{s}_d{d}",
            "us_per_call": round(us, 1),
            "derived": (f"xla_ref_us={us:.0f} vmem_per_step={vmem / 1e3:.0f}KB "
                        f"mxu_flops_per_step={flops_blk / 1e6:.1f}M "
                        f"hbm_savings=O(S^2) scores never materialised"),
        })

    # fused MLP: HBM traffic saved = 2*t*f*bytes (intermediate round-trip)
    for t, dm, f_ in [(1024, 512, 2048)]:
        x = jax.random.normal(key, (t, dm), jnp.bfloat16)
        wg = jax.random.normal(key, (dm, f_), jnp.bfloat16) * 0.05
        wu = jax.random.normal(key, (dm, f_), jnp.bfloat16) * 0.05
        wd = jax.random.normal(key, (f_, dm), jnp.bfloat16) * 0.05
        g = jax.jit(lambda *a: ref.fused_mlp_ref(*a))
        us = _time(g, x, wg, wu, wd)
        saved = 2 * t * f_ * 2
        rows.append({
            "name": f"kernel/fused_mlp_t{t}",
            "us_per_call": round(us, 1),
            "derived": (f"xla_ref_us={us:.0f} "
                        f"hbm_saved_per_call={saved / 1e6:.1f}MB "
                        f"(gated intermediate stays in VMEM)"),
        })

    # SSD scan: state stays in VMEM across chunks
    b, s, h, p, n = 1, 2048, 4, 64, 64
    x = jax.random.normal(key, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (b, s, h)))
    A = -jnp.exp(jax.random.normal(key, (h,)))
    B = jax.random.normal(key, (b, s, n)) * 0.3
    C = jax.random.normal(key, (b, s, n)) * 0.3
    gf = jax.jit(lambda *a: ref.ssd_scan_ref(*a))
    us = _time(gf, x, dt, A, B, C)
    rows.append({
        "name": f"kernel/ssd_scan_s{s}",
        "us_per_call": round(us, 1),
        "derived": (f"xla_seq_ref_us={us:.0f} "
                    f"state_vmem={n * p * 4 / 1e3:.0f}KB "
                    f"chunked_kernel=QxQ MXU matmuls vs seq scan"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
