"""Serve-engine throughput: tokens/s vs. slot count on a tiny config.

The point of the batched slot-table decode is that one engine step costs
ONE device program regardless of occupancy, so tokens/s should GROW with
the slot count on a fixed request workload (the per-slot-dispatch engine
it replaced was flat). Each slot count serves the same workload twice and
times the second pass, so compile/trace time is excluded.

Each row also reports ``peak_kv_bytes`` — the engine's resident decode
state. The dense layout grows it linearly in slots (slots * max_len rows
whether or not requests are short); the paged layout (--paged) holds one
shared page pool, sizable via --kv-pages independently of the slot count,
which is the fragmentation win the paged tests pin down.

``--tp`` / ``--dp`` serve the same workload through the sharded paths
(serve/parallel.py): tp shards the one-trace decode program + KV pool
over that many devices, dp replicates engines behind the least-load
router; ``--parallel-sweep`` crosses tp in {1,2,4} x dp in {1,2} and
reports tokens/s plus per-device peak KV bytes per cell (the acceptance
signal: per-device KV ~ 1/tp of the unsharded pool, one decode trace per
replica throughout). Any of the three forces 8 virtual host devices
before jax initializes; override via XLA_FLAGS.

``--mixed-workload`` runs the chunked-prefill comparison instead: a
long/short-interleaved prompt mix on the paged layout, each slot count
served once with the legacy split prefill/decode path (``mixed=False``)
and once with the unified mixed token-slot step (``--chunk-tokens``
budget) — the rows pin TTFT p50/p90/p99 with chunking off vs. on and
the mixed path's bounded trace count (the CI ``mixed-batch-smoke`` job
asserts both).

CLI (JSON output, used by the CI smoke steps):

    PYTHONPATH=src:. python benchmarks/bench_serve_throughput.py \
        --slots 1 2 4 --requests 8 --max-new 8 --json out.json
"""
from __future__ import annotations

import os
import sys

if any(a.startswith(("--tp", "--dp", "--parallel-sweep"))
       for a in sys.argv):
    # must land before jax (imported below via repro.models) initializes
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import argparse
import hashlib
import json
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.driver import AsyncDriver
from repro.serve.engine import ServeEngine
from repro.serve.parallel import ReplicaRouter, replica_meshes

TINY = ModelConfig(name="bench-serve", arch_type="dense", num_layers=2,
                   d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                   vocab_size=256, dtype="float32")


def _workload(rng, n_requests, mixed: bool = False,
              repetitive: bool = False):
    """Uniform short prompts by default; ``mixed=True`` interleaves LONG
    (40-56 token) and short (4-8) prompts — the chunked-prefill stress
    mix, where a long admission stalls every decoding slot unless
    prefill is chunked into the step budget. ``repetitive=True`` tiles a
    short random motif into each prompt — the prompt-lookup drafter's
    best case (templated/looping text), and the tiny model's greedy
    continuation of a periodic context quickly enters its own cycle, so
    the n-gram drafter keeps matching and ``--spec-k`` rows show > 1
    accepted token per decode step."""
    if repetitive:
        prompts = []
        for _ in range(n_requests):
            motif = rng.integers(0, TINY.vocab_size,
                                 size=(int(rng.integers(3, 6)),))
            reps = int(rng.integers(4, 7))
            prompts.append(np.tile(motif, reps).astype(np.int32))
        return prompts
    if mixed:
        return [rng.integers(
            0, TINY.vocab_size,
            size=(int(rng.integers(40, 57) if i % 2 == 0
                      else rng.integers(4, 9)),)).astype(np.int32)
            for i in range(n_requests)]
    return [rng.integers(0, TINY.vocab_size,
                         size=(int(rng.integers(4, 13)),)).astype(np.int32)
            for _ in range(n_requests)]


def bench(params, *, slots: int, n_requests: int, max_new: int,
          max_len: int = 64, seed: int = 0, paged: bool = False,
          page_size: int = 16, kv_pages=None, prefix_cache: bool = False,
          lazy: bool = False, tp: int = 1, dp: int = 1,
          mixed=None, chunk_tokens=None, mixed_workload: bool = False,
          attn_backend: str = "gather", spec_k: int = 0,
          drafter: str = "ngram", repetitive: bool = False,
          trace_level: int = 1, trace_out=None) -> dict:
    kw = dict(slots=slots, max_len=max_len, paged=paged,
              page_size=page_size, kv_pages=kv_pages,
              prefix_cache=prefix_cache, lazy=lazy,
              attn_backend=attn_backend, trace_level=trace_level)
    if mixed is not None:
        kw["mixed"] = mixed
    if chunk_tokens is not None:
        kw["chunk_tokens"] = chunk_tokens
    if spec_k > 0:
        from repro.serve.speculative import SpecConfig
        kw["spec"] = SpecConfig(k=spec_k, drafter=drafter)
        kw.setdefault("chunk_tokens", max(256, slots * (spec_k + 1)))
    if dp > 1:
        eng = ReplicaRouter(TINY, params, dp=dp, tp=tp, **kw)
    elif tp > 1:
        [mesh] = replica_meshes(1, tp)
        eng = ServeEngine(TINY, params, mesh=mesh, **kw)
    else:
        eng = ServeEngine(TINY, params, **kw)
    rng = np.random.default_rng(seed)
    prompts = _workload(rng, n_requests, mixed=mixed_workload,
                        repetitive=repetitive)

    # warm pass (batch run): traces decode + every prefill bucket
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=max_new)
    eng.run()
    # steady state: counters restart at zero, trace counters stay
    # monotonic so the one-trace CI assertion still covers BOTH passes
    eng.reset_stats()
    # measured pass through the AsyncDriver: deferred start means the
    # whole batch admits exactly like run() (same decode_steps), while
    # per-request TTFT/TPOT percentiles ride along for free
    drv = AsyncDriver(eng, start=False)
    streams = [drv.submit(p, max_new=max_new, rid=n_requests + i)
               for i, p in enumerate(prompts)]
    t0 = time.perf_counter()
    drv.start()
    drv.join(timeout=600.0)
    dt = time.perf_counter() - t0
    drv.stop(drain=False)
    outs = {s.rid: list(s.result(timeout=0.0).out) for s in streams}
    toks = sum(len(o) for o in outs.values())
    # greedy-token fingerprint of the measured pass: rows from different
    # backends (gather vs pallas) over the same workload must match it
    # exactly — the CI paged-kernel-smoke identity check
    digest = hashlib.sha1(json.dumps(
        [outs[r] for r in sorted(outs)]).encode()).hexdigest()[:16]
    lat = drv.metrics.latency_summary()
    st = eng.stats
    # trace counters are a PER-REPLICA property: report the worst replica
    # so "decode_traces == 1" means one trace in EVERY engine
    reps = st.get("replicas", [st])
    rep0 = eng.engines[0] if dp > 1 else eng
    # span coverage: phase laps over step wall time (the >= 0.95
    # acceptance bar); export covers BOTH passes — the tracer is not
    # reset with the counters, which is exactly what an operator wants
    from repro.serve.tracing import phase_coverage
    tracers = eng.tracers if hasattr(eng, "tracers") else [eng.tracer]
    coverage = round(phase_coverage(tracers), 4)
    if trace_out:
        eng.export_trace(trace_out)
    return {
        "slots": slots,
        "tp": tp,
        "dp": dp,
        "mixed": bool(getattr(rep0, "mixed", False)),
        "chunk_tokens": int(getattr(rep0, "chunk_tokens", 0)),
        "spec_k": spec_k,
        "drafter": drafter if spec_k > 0 else "",
        "spec_drafted": st.get("spec_drafted", 0),
        "spec_accepted": st.get("spec_accepted", 0),
        "spec_accept_rate": round(
            st.get("spec_accepted", 0) / max(st.get("spec_drafted", 0), 1),
            4),
        # decode tokens per (step, decoding slot) pair, prefill-sampled
        # firsts excluded: exactly 1.0 without speculation regardless of
        # occupancy, in (1, k+1] when drafts land
        "accepted_tokens_per_step": round(
            (st["decode_tokens"] - st["prefills"])
            / max(st.get("decode_slot_steps", 0), 1), 4),
        "requests": n_requests,
        "tokens": toks,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(toks / dt, 1),
        "decode_steps": st["decode_steps"],
        "decode_traces": max(r["decode_traces"] for r in reps),
        "prefill_traces": max(r["prefill_traces"] for r in reps),
        "prefill_chunk_tokens": st.get("prefill_chunk_tokens", 0),
        "paged": rep0.paged,
        "attn_backend": getattr(rep0, "attn_backend", "gather"),
        "trace_level": trace_level,
        "trace_phase_coverage": coverage,
        "out_digest": digest,
        "peak_kv_bytes": eng.kv_bytes(),
        "per_device_peak_kv_bytes": eng.per_device_kv_bytes(),
        # request latency percentiles (seconds, from the driver metrics)
        **{k: round(v, 6) for k, v in lat.items()},
        # pool telemetry (zeros on the dense layout / with sharing off)
        "pages_in_use": st["pages_in_use"],
        "peak_pages": st["peak_pages"],
        "prefix_hit_blocks": st["prefix_hit_blocks"],
        "prefix_miss_blocks": st["prefix_miss_blocks"],
        "preemptions": st["preemptions"],
        "cow_copies": st["cow_copies"],
    }


def run() -> list:
    """Harness entry (benchmarks/run.py CSV convention)."""
    params = get_model(TINY).init(__import__("jax").random.key(0), TINY)
    rows = []
    for paged in (False, True):
        for slots in (1, 2, 4, 8):
            r = bench(params, slots=slots, n_requests=8, max_new=8,
                      paged=paged)
            layout = "paged" if paged else "dense"
            rows.append({
                "name": f"serve/throughput_{layout}_slots{slots}",
                "us_per_call": round(
                    1e6 * r["wall_s"] / max(r["decode_steps"], 1), 1),
                "derived": (f"tok_per_s={r['tokens_per_s']} "
                            f"decode_steps={r['decode_steps']} "
                            f"decode_traces={r['decode_traces']} "
                            f"peak_kv_bytes={r['peak_kv_bytes']}"),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="use the paged (block-table) KV layout")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="paged pool size (default: dense-capacity parity)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree (shard the decode "
                         "program + KV pool; forces 8 host devices)")
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel replica count (least-load router)")
    ap.add_argument("--parallel-sweep", action="store_true",
                    help="sweep tp in {1,2,4} x dp in {1,2} on the paged "
                         "layout at the first --slots value")
    ap.add_argument("--mixed-workload", action="store_true",
                    help="chunked-prefill comparison: long/short prompt "
                         "mix on the paged layout, each slot count run "
                         "with mixed stepping OFF then ON")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="mixed-step token budget (engine default 256)")
    ap.add_argument("--attn-backend", choices=("gather", "pallas"),
                    default="gather",
                    help="paged-attention decode path (pallas = fused "
                         "flash-decoding kernel, interpret mode on CPU; "
                         "implies --paged); rows carry the backend and "
                         "an out_digest column so gather-vs-pallas runs "
                         "can be diffed for token identity")
    ap.add_argument("--spec-k", type=int, default=0, metavar="K",
                    help="speculative decode: draft up to K tokens per "
                         "slot per step, verified in the same mixed "
                         "dispatch (0 disables; implies --paged; rows "
                         "gain spec_accept_rate and "
                         "accepted_tokens_per_step columns, and "
                         "out_digest must equal the spec-off run's — "
                         "the CI speculative-smoke identity check)")
    ap.add_argument("--drafter", choices=("ngram", "model"),
                    default="ngram",
                    help="--spec-k drafter: 'ngram' prompt lookup or "
                         "'model' (tiny fresh-params draft model)")
    ap.add_argument("--repetitive", action="store_true",
                    help="tile short random motifs into every prompt — "
                         "the prompt-lookup drafter's best case; the "
                         "workload the speculative-smoke job drives")
    ap.add_argument("--trace-level", type=int, choices=(0, 1, 2),
                    default=1,
                    help="engine tracer detail: 0 off, 1 lifecycle + "
                         "phase records (default), 2 per-chunk detail; "
                         "rows carry trace_phase_coverage (phase laps "
                         "over step wall time)")
    ap.add_argument("--trace-out", type=str, default="", metavar="PATH",
                    help="write the LAST bench row's Chrome/Perfetto "
                         "trace_event JSON to PATH (with "
                         "--mixed-workload that is the mixed-mode row "
                         "at the largest slot count)")
    ap.add_argument("--json", type=str, default="",
                    help="write results to this path (default: stdout)")
    args = ap.parse_args()

    import jax
    params = get_model(TINY).init(jax.random.key(0), TINY)
    if args.parallel_sweep:
        results = [bench(params, slots=args.slots[0],
                         n_requests=args.requests, max_new=args.max_new,
                         max_len=args.max_len, paged=True,
                         page_size=args.page_size, kv_pages=args.kv_pages,
                         tp=tp, dp=dp, trace_level=args.trace_level,
                         trace_out=args.trace_out or None)
                   for tp in (1, 2, 4) for dp in (1, 2)
                   if tp * dp <= jax.device_count()]
    elif args.mixed_workload:
        # spec rides on the mixed step only, so the split (mixed=False)
        # baseline rows always run spec-off; the mixed rows carry
        # --spec-k so the long/short mix reports accept rate and
        # accepted tokens/step next to TTFT
        results = [bench(params, slots=s, n_requests=args.requests,
                         max_new=args.max_new, max_len=args.max_len,
                         paged=True, page_size=args.page_size,
                         kv_pages=args.kv_pages, mixed=mixed,
                         chunk_tokens=args.chunk_tokens,
                         mixed_workload=True,
                         spec_k=args.spec_k if mixed else 0,
                         drafter=args.drafter,
                         trace_level=args.trace_level,
                         trace_out=args.trace_out or None)
                   for s in args.slots for mixed in (False, True)]
    else:
        results = [bench(params, slots=s, n_requests=args.requests,
                         max_new=args.max_new, max_len=args.max_len,
                         paged=(args.paged or args.tp > 1 or args.dp > 1
                                or args.attn_backend == "pallas"
                                or args.spec_k > 0),
                         page_size=args.page_size, kv_pages=args.kv_pages,
                         tp=args.tp, dp=args.dp,
                         attn_backend=args.attn_backend,
                         spec_k=args.spec_k, drafter=args.drafter,
                         repetitive=args.repetitive,
                         trace_level=args.trace_level,
                         trace_out=args.trace_out or None)
                   for s in args.slots]
    report = {"config": TINY.name, "results": results}
    out = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
        base = results[0]["tokens_per_s"]
        for r in results:
            mode = " mixed" if r["mixed"] else " split"
            if r["spec_k"]:
                mode += (f" spec{r['spec_k']}/{r['drafter']} "
                         f"acc={r['spec_accept_rate']:.2f} "
                         f"tok/step={r['accepted_tokens_per_step']:.2f}")
            print(f"slots={r['slots']:>2} tp{r['tp']} dp{r['dp']}{mode} "
                  f"{r['tokens_per_s']:>8.1f} tok/s "
                  f"({r['tokens_per_s'] / base:.2f}x, "
                  f"{r['decode_steps']} decode calls, "
                  f"{r['decode_traces']} trace/replica, "
                  f"kv {r['peak_kv_bytes'] / 1e6:.2f}MB global / "
                  f"{r['per_device_peak_kv_bytes'] / 1e6:.2f}MB per dev) "
                  f"ttft p50/p90/p99 {r['ttft_p50_s'] * 1e3:.1f}/"
                  f"{r['ttft_p90_s'] * 1e3:.1f}/"
                  f"{r['ttft_p99_s'] * 1e3:.1f}ms "
                  f"tpot {r['tpot_p50_s'] * 1e3:.2f}/"
                  f"{r['tpot_p90_s'] * 1e3:.2f}/"
                  f"{r['tpot_p99_s'] * 1e3:.2f}ms")
    else:
        print(out)


if __name__ == "__main__":
    main()
