"""Paper §5.1 (Korthikanti et al.): activation-memory equations.

(a) Reproduce the equations' predictions across tensor-parallel degree t
    for the paper's flagship config and verify the claimed structure:
    lim t->inf of the no-SP footprint is 10·s·b·h (the un-parallelised
    dropout/layer-norm floor), while SP scales the WHOLE footprint by 1/t.
(b) Cross-check against a real lowered module: per-device activation bytes
    of a 1-layer block with and without sequence parallelism on a 1x4 mesh
    — the SP build must carry strictly fewer per-device bytes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.costmodel import activation_bytes_per_layer
from repro.configs import get_config
from repro.launch.mesh import make_mesh


def run() -> list:
    rows = []
    cfg = get_config("qwen3-14b")
    s, b = 4096, 1
    sbh = s * b * cfg.d_model
    for t in (1, 2, 8, 64, 10**6):
        t0 = time.perf_counter_ns()
        no_sp = activation_bytes_per_layer(cfg, b, s, t, False)
        sp = activation_bytes_per_layer(cfg, b, s, t, True)
        us = (time.perf_counter_ns() - t0) / 1e3
        rows.append({
            "name": f"korthikanti/t{t}",
            "us_per_call": round(us, 1),
            "derived": (f"no_sp={no_sp / sbh:.2f}sbh sp={sp / sbh:.2f}sbh "
                        f"ratio={no_sp / sp:.2f}"),
        })
    floor = activation_bytes_per_layer(cfg, b, s, 10**6, False) / sbh
    rows.append({"name": "korthikanti/limit_floor",
                 "us_per_call": 0.0,
                 "derived": f"limit={floor:.3f}sbh expect=10sbh "
                            f"holds={abs(floor - 10) < 0.01}"})

    # (b) measured: 1 layer fwd under jit, with/without SP constraints
    if len(jax.devices()) >= 4:
        mesh = make_mesh((1, 4), ("data", "model"))
        d, f, tt = 512, 2048, 2048

        def block(x, wg, wd, sp):
            h = x @ wg                                       # (t, f) sharded
            h = jax.nn.gelu(h)
            y = h @ wd
            spec = P("model", None) if sp else P(None, None)
            y = jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, spec))
            return jnp.tanh(y).sum()

        x = jax.ShapeDtypeStruct((tt, d), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, None)))
        wg = jax.ShapeDtypeStruct((d, f), jnp.float32,
                                  sharding=NamedSharding(mesh,
                                                         P(None, "model")))
        wd = jax.ShapeDtypeStruct((f, d), jnp.float32,
                                  sharding=NamedSharding(mesh,
                                                         P("model", None)))
        sizes = {}
        for sp in (False, True):
            comp = jax.jit(jax.grad(lambda x, a, b_: block(x, a, b_, sp)),
                           ).lower(x, wg, wd).compile()
            mem = comp.memory_analysis()
            sizes[sp] = mem.temp_size_in_bytes
        rows.append({"name": "korthikanti/measured_sp_smaller",
                     "us_per_call": 0.0,
                     "derived": (f"no_sp_temp={sizes[False]} "
                                 f"sp_temp={sizes[True]} "
                                 f"holds={sizes[True] <= sizes[False]}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
