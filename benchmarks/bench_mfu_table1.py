"""Paper Table 1: utilisation of GPT-3 / Gopher / MT-NLG / PaLM.

We re-predict each system's MFU with the analytical cost model on its OWN
hardware + published parallelisation degrees, and report predicted vs the
survey's reported number. Matching within a factor ~1.5x validates that the
cost model captures the regime each system sits in (the survey's point:
PaLM > Gopher > MT-NLG > GPT-3)."""
from __future__ import annotations

import time

from repro.configs.base import ShapeConfig
from repro.core.costmodel import estimate
from benchmarks.paper_cases import TABLE1


def run() -> list:
    rows = []
    for name, cfg, hw, chips, deg, batch, seq, reported in TABLE1:
        t0 = time.perf_counter_ns()
        shape = ShapeConfig(name="case", seq_len=seq, global_batch=batch,
                            kind="train")
        cb = estimate(cfg, shape, deg, hw)
        us = (time.perf_counter_ns() - t0) / 1e3
        pred = cb.mfu * 100
        rows.append({
            "name": f"table1/{name}",
            "us_per_call": round(us, 1),
            "derived": (f"pred_mfu={pred:.1f}% reported={reported}% "
                        f"ratio={pred / reported:.2f} "
                        f"bottleneck={'coll' if cb.t_collective > cb.t_compute else 'comp'}"),
        })
    # ordering check — the survey's qualitative claim
    preds = {r["name"].split("/")[1]: float(r["derived"].split("=")[1]
                                            .split("%")[0]) for r in rows}
    ok = preds["PaLM"] > preds["Gopher"] and preds["PaLM"] > preds["GPT-3"]
    rows.append({"name": "table1/ordering_palm_highest",
                 "us_per_call": 0.0, "derived": f"holds={ok}"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
