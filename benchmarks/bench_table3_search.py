"""Paper Table 3: auto-parallelisation frameworks and their search methods.
We benchmark our three search methods (exhaustive / DP / MCMC — the
PipeDream / Alpa / FlexFlow archetypes) on identical inputs: wall time,
evaluations, and solution quality relative to the exhaustive floor —
the standardised comparison the survey says the field lacks."""
from __future__ import annotations

import time

from repro.configs import SHAPES, get_config
from repro.core.planner import SEARCH_METHODS, plan

ARCHS = ["qwen3-14b", "olmoe-1b-7b", "deepseek-coder-33b", "mamba2-780m"]


def run() -> list:
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        shape = SHAPES["train_4k"]
        floor = None
        for method in SEARCH_METHODS:
            t0 = time.perf_counter_ns()
            p = plan(cfg, shape, 256, method=method)
            us = (time.perf_counter_ns() - t0) / 1e3
            if method == "exhaustive":
                floor = p.cost
            d = p.degrees
            rows.append({
                "name": f"table3/{arch}/{method}",
                "us_per_call": round(us, 1),
                "derived": (f"cost={p.cost:.3f}s quality={floor / p.cost:.3f} "
                            f"evals={p.evaluations} "
                            f"plan=dp{d.dp}xtp{d.tp}xpp{d.pp}m{d.microbatches}"
                            f"{'sp' if d.seq_parallel else ''}"),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
