"""Shared-prefix KV cache: tokens/s and peak KV residency vs. how much of
the workload shares a system prompt.

Each workload is R requests whose prompts are ``system_prefix + random
suffix``; the share fraction controls how many requests use the COMMON
system prefix (the rest get private random prefixes of the same length,
so total prompt tokens are identical across fractions). Every workload is
served twice on identically sized pools — ``sequential`` (prefix_cache
off: every request holds a private copy of its prefix, the PR 3
behaviour) vs ``shared`` (prefix_cache + lazy growth: one refcounted
physical copy per distinct prefix) — and reports:

  * ``tokens_per_s`` on a second, fully traced pass (compile excluded);
  * ``peak_pages`` / ``peak_kv_bytes`` — the pool high-water mark and the
    bytes it pins (the pool array itself is allocated up front, so the
    high-water mark is the honest residency number: it is what a
    right-sized ``kv_pages`` must cover);
  * prefix hit/miss block counters and the decode trace count (sharing
    must not add programs).

At 100% sharing the N requests keep ONE copy of the 64-token prefix, so
peak residency drops by ~(N-1) * prefix_pages versus sequential; at 0%
the two engines match (the radix tree finds nothing to share).

CLI (JSON output, used by the CI smoke step):

    PYTHONPATH=src:. python benchmarks/bench_prefix_cache.py \
        --requests 8 --prefix-len 64 --json out.json
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.engine import ServeEngine

TINY = ModelConfig(name="bench-prefix", arch_type="dense", num_layers=2,
                   d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                   vocab_size=256, dtype="float32")


def _workload(rng, n_requests: int, prefix_len: int, suffix_len: int,
              share_frac: float):
    """Prompts of identical length; ``share_frac`` of them open with the
    same system prefix, the rest with private random prefixes."""
    system = rng.integers(0, TINY.vocab_size, size=(prefix_len,))
    n_shared = round(n_requests * share_frac)
    prompts = []
    for i in range(n_requests):
        head = system if i < n_shared else \
            rng.integers(0, TINY.vocab_size, size=(prefix_len,))
        tail = rng.integers(0, TINY.vocab_size, size=(suffix_len,))
        prompts.append(np.concatenate([head, tail]).astype(np.int32))
    return prompts


def bench(params, *, share_frac: float, shared: bool, n_requests: int = 8,
          prefix_len: int = 64, suffix_len: int = 8, max_new: int = 8,
          max_len: int = 128, page_size: int = 16, seed: int = 0) -> dict:
    eng = ServeEngine(TINY, params, slots=n_requests, max_len=max_len,
                      paged=True, page_size=page_size,
                      prefix_cache=shared, lazy=shared)
    rng = np.random.default_rng(seed)
    prompts = _workload(rng, n_requests, prefix_len, suffix_len, share_frac)

    def serve(rid0):
        for i, p in enumerate(prompts):
            eng.submit(rid0 + i, p, max_new=max_new)
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0
        toks = sum(len(results[rid0 + i].out) for i in range(n_requests))
        assert all(results[rid0 + i].done for i in range(n_requests))
        return toks, dt

    serve(0)                                   # warm: traces decode+buckets
    eng.release_prefix_cache()                 # second pass re-populates
    steps0 = eng.stats["decode_steps"]
    toks, dt = serve(n_requests)               # measured pass, fully traced
    pool_bytes = eng.kv_bytes()
    page_bytes = pool_bytes / (eng.kv_pages + 1)   # +1: the null page
    return {
        "share_frac": share_frac,
        "mode": "shared" if shared else "sequential",
        "requests": n_requests,
        "prefix_len": prefix_len,
        "tokens": toks,
        "wall_s": round(dt, 4),
        "tokens_per_s": round(toks / dt, 1),
        "decode_steps": eng.stats["decode_steps"] - steps0,
        "decode_traces": eng.stats["decode_traces"],
        # never reset: the engine-lifetime high-water mark
        "peak_pages": eng.stats["peak_pages"],
        "pool_pages": eng.kv_pages,
        "peak_kv_bytes": int(eng.stats["peak_pages"] * page_bytes),
        "pool_kv_bytes": pool_bytes,
        "prefix_hit_blocks": eng.stats["prefix_hit_blocks"],
        "prefix_miss_blocks": eng.stats["prefix_miss_blocks"],
        "preemptions": eng.stats["preemptions"],
        "cow_copies": eng.stats["cow_copies"],
    }


def run() -> list:
    """Harness entry (benchmarks/run.py CSV convention)."""
    params = get_model(TINY).init(__import__("jax").random.key(0), TINY)
    rows = []
    for frac in (0.0, 0.5, 1.0):
        for shared in (False, True):
            r = bench(params, share_frac=frac, shared=shared)
            rows.append({
                "name": f"serve/prefix_{r['mode']}_share{int(frac * 100)}",
                "us_per_call": round(
                    1e6 * r["wall_s"] / max(r["decode_steps"], 1), 1),
                "derived": (f"tok_per_s={r['tokens_per_s']} "
                            f"peak_pages={r['peak_pages']} "
                            f"hit_blocks={r['prefix_hit_blocks']} "
                            f"decode_traces={r['decode_traces']}"),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--suffix-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--fracs", type=float, nargs="+", default=[0.0, 0.5, 1.0])
    ap.add_argument("--json", type=str, default="",
                    help="write results to this path (default: stdout)")
    args = ap.parse_args()

    import jax
    params = get_model(TINY).init(jax.random.key(0), TINY)
    results = [bench(params, share_frac=f, shared=s,
                     n_requests=args.requests, prefix_len=args.prefix_len,
                     suffix_len=args.suffix_len, max_new=args.max_new,
                     max_len=args.max_len, page_size=args.page_size)
               for f in args.fracs for s in (False, True)]
    report = {"config": TINY.name, "results": results}
    out = json.dumps(report, indent=2)
    if args.json:
        with open(args.json, "w") as f:
            f.write(out + "\n")
        for r in results:
            print(f"share={int(r['share_frac'] * 100):>3}% "
                  f"{r['mode']:>10} {r['tokens_per_s']:>8.1f} tok/s  "
                  f"peak {r['peak_pages']:>3}/{r['pool_pages']} pages "
                  f"({r['peak_kv_bytes'] / 1e6:.2f}MB)  "
                  f"hits {r['prefix_hit_blocks']} "
                  f"traces {r['decode_traces']}")
    else:
        print(out)


if __name__ == "__main__":
    main()
