"""Deliverable (g): the roofline table, read from the dry-run artifacts in
experiments/dryrun/. One row per (arch x shape x mesh): the three terms,
the bottleneck, and MODEL_FLOPS/HLO_FLOPs."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def run() -> list:
    rows = []
    for fn in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(fn.read_text())
        if rec.get("status") != "ok":
            rows.append({"name": f"roofline/{fn.stem}", "us_per_call": 0.0,
                         "derived": rec.get("status", "?")})
            continue
        r = rec["roofline"]
        rows.append({
            "name": f"roofline/{fn.stem}",
            "us_per_call": round(rec.get("compile_s", 0) * 1e6, 0),
            "derived": (f"comp={r['t_compute_s']:.2e}s "
                        f"mem={r['t_memory_s']:.2e}s "
                        f"coll={r['t_collective_s']:.2e}s "
                        f"bottleneck={r['bottleneck']} "
                        f"useful={r['useful_ratio']:.2f} "
                        f"mem/dev={r['mem_per_device_gb']:.1f}GB"),
        })
    if not rows:
        rows.append({"name": "roofline/none", "us_per_call": 0.0,
                     "derived": "run launch/dryrun.py first"})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
