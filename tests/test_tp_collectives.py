"""Validate the paper's §5.1 Megatron claim from the lowered HLO:

column-split A then row-split B needs exactly ONE all-reduce in the MLP
forward; the naive row-split-A scheme needs communication BEFORE the
nonlinearity too. We lower both on a 1x4 mesh and count collectives."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import make_mesh


def _mesh():
    return make_mesh((1, 4), ("data", "model"))


def _counts(compiled):
    s = analyze(compiled.as_text())
    return {k: v for k, v in s.collectives.items() if v > 0}


def test_megatron_mlp_single_allreduce_forward():
    mesh = _mesh()
    d, f, t = 256, 1024, 64
    x = jax.ShapeDtypeStruct((t, d), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None)))
    a = jax.ShapeDtypeStruct((d, f), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    b = jax.ShapeDtypeStruct((f, d), jnp.float32,
                             sharding=NamedSharding(mesh, P("model", None)))

    def mlp(x, a, b):
        return jax.nn.gelu(x @ a) @ b

    comp = jax.jit(mlp, out_shardings=NamedSharding(mesh, P(None, None))
                   ).lower(x, a, b).compile()
    summary = analyze(comp.as_text())
    n_ar = summary.collectives.get("all-reduce", 0) / (t * d * 4)
    assert n_ar == pytest.approx(1.0), summary.collectives
    assert "all-gather" not in summary.collectives


def test_row_first_split_requires_earlier_comm():
    """Splitting A over ROWS forces communication before the GeLU —
    the scheme the paper shows is worse (Fig. 6c)."""
    mesh = _mesh()
    d, f, t = 256, 1024, 64
    x = jax.ShapeDtypeStruct((t, d), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, "model")))
    a = jax.ShapeDtypeStruct((d, f), jnp.float32,
                             sharding=NamedSharding(mesh, P("model", None)))
    b = jax.ShapeDtypeStruct((f, d), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None)))

    def mlp(x, a, b):
        # force the mathematical structure: GeLU applied to the FULL sum
        h = jax.lax.with_sharding_constraint(
            x @ a, NamedSharding(mesh, P(None, None)))
        return jax.nn.gelu(h) @ b

    comp = jax.jit(mlp).lower(x, a, b).compile()
    s = analyze(comp.as_text())
    # communication volume before the nonlinearity: t*f gathered vs t*d
    comm = sum(s.collectives.values())
    assert comm >= t * f * 4, s.collectives  # f >> d: strictly worse


def test_attention_tp_single_allreduce():
    """QKV column-split by head + out-proj row-split: one fwd all-reduce."""
    mesh = _mesh()
    t, h, dh, d = 64, 8, 32, 256
    x = jax.ShapeDtypeStruct((t, d), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None)))
    wqkv = jax.ShapeDtypeStruct((d, 3 * h * dh), jnp.float32,
                                sharding=NamedSharding(mesh,
                                                       P(None, "model")))
    wo = jax.ShapeDtypeStruct((h * dh, d), jnp.float32,
                              sharding=NamedSharding(mesh, P("model", None)))

    def attn(x, wqkv, wo):
        qkv = (x @ wqkv).reshape(t, 3, h, dh)
        q, k, v = qkv[:, 0], qkv[:, 1], qkv[:, 2]
        s = jnp.einsum("shd,thd->hst", q, k)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("hst,thd->shd", p, v).reshape(t, h * dh)
        return ctx @ wo

    comp = jax.jit(attn, out_shardings=NamedSharding(mesh, P(None, None))
                   ).lower(x, wqkv, wo).compile()
    s = analyze(comp.as_text())
    n_ar = s.collectives.get("all-reduce", 0) / (t * d * 4)
    assert n_ar == pytest.approx(1.0), s.collectives
