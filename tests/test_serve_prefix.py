"""Shared-prefix KV cache + admission scheduler: prefix-shared, lazily
grown and preempted/requeued requests stay TOKEN-IDENTICAL to sequential
greedy decode (dense / MoE / enc-dec) with exactly one decode trace; N
shared-prefix requests fit a pool sized for a fraction of them unshared;
a pool sized below aggregate demand drains via preempt/requeue instead of
deadlocking. The allocator-level refcount/CoW property suite is
tests/test_paged_allocator.py (hypothesis) and its seeded twin in
tests/test_serve_paged.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Session
from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.paging import pages_for
from repro.serve.scheduler import FifoLeastProgress
from repro.serve.step import greedy_generate

CFG = ModelConfig(name="prefix-dense", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

MOE_CFG = ModelConfig(name="prefix-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="prefix-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _sequential(params, cfg, prompts, new, frames=None):
    out = {}
    for i, p in enumerate(prompts):
        batch = {"tokens": jnp.asarray(p)[None]}
        if frames is not None:
            batch["frames"] = jnp.asarray(frames[i])[None]
        toks = greedy_generate(params, cfg, Strategy(), batch, steps=new)
        out[i] = [int(t) for t in toks[0]]
    return out


# ------------------------------------------------------------------ parity

def test_prefix_shared_matches_sequential_dense():
    """8 requests opening with the same 64-token system prompt, staggered
    through 3 slots: prefix-shared + lazy outputs are byte-identical to
    per-request greedy decode, with ONE decode trace and real block
    reuse."""
    params = _params(CFG)
    rng = np.random.default_rng(0)
    system = rng.integers(0, CFG.vocab_size, size=(64,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size,
                              size=(int(n),)).astype(np.int32)])
        for n in (4, 5, 6, 7, 4, 5, 6, 7)]
    expected = _sequential(params, CFG, prompts, 6)
    eng = ServeEngine(CFG, params, slots=3, max_len=128, paged=True,
                      page_size=16, prefix_cache=True, lazy=True)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=6)
    results = eng.run()
    for i in expected:
        assert results[i].done
        assert results[i].out == expected[i], (i, results[i].out)
    assert eng.stats["decode_traces"] == 1
    # 7 followers x 4 shared system-prompt blocks were served from cache
    assert eng.stats["prefix_hit_blocks"] >= 7 * 4


def test_prefix_shared_matches_sequential_moe_identical_prompts():
    """MoE keys the prefix cache on the FULL context (capacity routing
    makes block KV portable only between identical sequences), so
    repeated prompts dedup to one physical copy — and single-slot decode
    stays token-identical to sequential."""
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(6)
    p = rng.integers(0, MOE_CFG.vocab_size, size=(11,)).astype(np.int32)
    q = rng.integers(0, MOE_CFG.vocab_size, size=(9,)).astype(np.int32)
    prompts = [p, p, q, p]
    expected = _sequential(params, MOE_CFG, prompts, 4)
    eng = ServeEngine(MOE_CFG, params, slots=1, max_len=32, paged=True,
                      page_size=4, prefix_cache=True, lazy=True)
    for i, pr in enumerate(prompts):
        eng.submit(i, pr, max_new=4)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out)
    # repeats of p share its two full 4-token blocks; q matches nothing
    assert eng.stats["prefix_hit_blocks"] >= 4
    assert eng.stats["decode_traces"] == 1


def test_prefix_shared_matches_sequential_encdec_frames_salt():
    """Enc-dec decoder KV depends on the encoder output too, so the cache
    keys on a digest of the frames: same audio + same prompt prefix
    shares, same prompt under DIFFERENT audio must not (and stays
    exact)."""
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    system = rng.integers(0, AUDIO_CFG.vocab_size,
                          size=(8,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, AUDIO_CFG.vocab_size,
                              size=(n,)).astype(np.int32)])
        for n in (3, 4)] + [None]
    prompts[2] = prompts[0].copy()          # same tokens, other audio
    f1 = rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
    f2 = rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
    frames = [f1, f1, f2]
    expected = _sequential(params, AUDIO_CFG, prompts, 5, frames=frames)
    eng = ServeEngine(AUDIO_CFG, params, slots=2, max_len=32, paged=True,
                      page_size=4, prefix_cache=True, lazy=True)
    for i, (pr, fr) in enumerate(zip(prompts, frames)):
        eng.submit(i, pr, max_new=5, frames=fr)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out)
    # request 1 shares request 0's two system blocks (same f1 salt);
    # request 2 shares nothing despite identical tokens (f2 salt)
    assert eng.stats["prefix_hit_blocks"] == 2
    assert eng.stats["decode_traces"] == 1


def test_cow_tail_share_and_writer_isolation():
    """A prompt that stops MID-BLOCK of a cached longer prompt adopts the
    donor's page for its tail (partial hit) and must copy-on-write before
    its first decode write — the donor's page stays intact for later
    hits."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(1)
    base = rng.integers(0, CFG.vocab_size, size=(16,)).astype(np.int32)
    expected = _sequential(params, CFG, [base, base[:10]], 6)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=8, prefix_cache=True, lazy=True)
    eng.submit(0, base, max_new=6)
    r0 = eng.run()                      # donor retires; blocks stay cached
    eng.submit(1, base[:10], max_new=6)
    r1 = eng.run()                      # tail lands inside donor's block 1
    assert r0[0].out == expected[0]
    assert r1[1].out == expected[1]
    assert eng.stats["prefix_tail_hits"] == 1
    assert eng.stats["cow_copies"] == 1
    # writer isolation, device-side: the donor's pages were NOT clobbered
    eng.submit(2, base, max_new=6)
    r2 = eng.run()
    assert r2[2].out == expected[0]
    assert eng.stats["decode_traces"] == 1


def test_lazy_only_matches_sequential():
    """Lazy growth without sharing: reservations grow across page
    boundaries mid-decode and outputs stay exact (generous pool — no
    preemption needed)."""
    params = _params(CFG)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, CFG.vocab_size,
                            size=(int(n),)).astype(np.int32)
               for n in (5, 9, 7, 13)]
    expected = _sequential(params, CFG, prompts, 8)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=4, lazy=True)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=8)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i]
    assert eng.stats["preemptions"] == 0
    assert eng.stats["decode_traces"] == 1
    # lazy admission reserved prompt+1, nowhere near the worst case
    assert eng.stats["peak_pages"] < eng.kv_pages


# ------------------------------------------------------- memory regression

def test_8_shared_prefix_requests_fit_2_unshared_budget():
    """The acceptance bar: 8 requests sharing a 64-token system prompt
    are ALL resident on a pool sized for 2 unshared requests (the shared
    prefix is held once), token-identical to sequential decode — while
    the same engine without sharing can only hold 2."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(4)
    ps = 8
    system = rng.integers(0, CFG.vocab_size, size=(64,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size,
                              size=(4,)).astype(np.int32)])
        for _ in range(8)]
    # worst-case context: 68 prompt + 4 new - 1 = 71 tokens -> 9 pages;
    # the pool holds exactly two unshared requests' worth
    pool = 2 * pages_for(min(68 + 4 - 1, 128), ps)
    assert pool == 18
    expected = _sequential(params, CFG, prompts, 4)

    unshared = ServeEngine(CFG, params, slots=8, max_len=128, paged=True,
                           page_size=ps, kv_pages=pool, lazy=True)
    shared = ServeEngine(CFG, params, slots=8, max_len=128, paged=True,
                         page_size=ps, kv_pages=pool, prefix_cache=True,
                         lazy=True)
    for i, p in enumerate(prompts):
        unshared.submit(i, p, max_new=4)
        shared.submit(i, p, max_new=4)
    unshared.step()
    shared.step()
    assert sum(r is not None for r in unshared.active) == 2
    assert sum(r is not None for r in shared.active) == 8
    ru, rs = unshared.run(), shared.run()
    for i in expected:
        assert rs[i].done and rs[i].out == expected[i]
        assert ru[i].done and ru[i].out == expected[i]
    # 8 system-prompt pages held ONCE + 8 private tail pages
    assert shared.stats["peak_pages"] <= 8 + 8
    assert shared.stats["prefix_hit_blocks"] >= 7 * 8
    assert shared.stats["decode_traces"] == 1
    # drained: live requests gone, only cached prefix blocks remain
    assert shared._alloc.pages_in_use == len(shared._prefix) > 0
    shared.release_prefix_cache()
    assert shared._alloc.pages_in_use == 0
    assert shared._alloc.free_pages == shared.kv_pages


# ----------------------------------------------------- preemption liveness

@pytest.mark.parametrize("kv_pages,prefix", [(8, False), (8, True),
                                             (4, False)])
def test_preemption_liveness_pool_below_demand(kv_pages, prefix):
    """A pool deliberately sized below aggregate demand drains EVERY
    request via evict/preempt/requeue — no deadlock, no dropped request,
    outputs still byte-identical to sequential decode, one trace."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, CFG.vocab_size,
                            size=(6,)).astype(np.int32) for _ in range(5)]
    expected = _sequential(params, CFG, prompts, 10)
    eng = ServeEngine(CFG, params, slots=4, max_len=64, paged=True,
                      page_size=4, kv_pages=kv_pages, lazy=True,
                      prefix_cache=prefix)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=10)   # demand: 5 * 4 pages > kv_pages
    results = eng.run()
    assert all(results[i].done for i in range(5))
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out)
    assert eng.stats["preemptions"] >= 1
    assert eng.stats["decode_traces"] == 1
    if prefix:
        eng.release_prefix_cache()
    assert eng._alloc.pages_in_use == 0


def test_lazy_reserve_clamped_to_worst_case():
    """Regression: lazy admission must never demand MORE pages than the
    worst case submit() validated — a max_new=1 request with a
    page-aligned prompt needs NO decode page (it finishes on the prefill
    token), so it must drain on a pool of exactly pages_for(prompt)."""
    params = _params(CFG, seed=1)
    prompt = np.arange(16, dtype=np.int32)        # exactly one 16-tok page
    expected = _sequential(params, CFG, [prompt], 1)
    eng = ServeEngine(CFG, params, slots=1, max_len=32, paged=True,
                      page_size=16, kv_pages=1, lazy=True)
    eng.submit(0, prompt, max_new=1)              # worst case: 1 page == pool
    results = eng.run(max_steps=50)
    assert results[0].done
    assert results[0].out == expected[0]
    assert eng._alloc.pages_in_use == 0


def test_preempted_partials_survive_max_steps():
    """Preempted-and-requeued requests surface as done=False partials on
    max_steps exhaustion (nothing vanishes), and a later run() finishes
    them."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(6)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=4, kv_pages=4, lazy=True)
    for i in range(3):
        eng.submit(i, rng.integers(0, CFG.vocab_size, size=(5,)),
                   max_new=12)
    results = eng.run(max_steps=4)
    assert set(results) == {0, 1, 2}
    assert any(not r.done for r in results.values())
    assert all(r.done for r in eng.run().values())


# ----------------------------------------------------- policy + validation

def test_scheduler_policy_object():
    sched = FifoLeastProgress()
    assert sched.next_index([]) is None
    assert sched.next_index(["a", "b"]) == 0
    # least progress wins; slot index breaks ties deterministically
    assert sched.pick_victim([(0, 5), (1, 2), (2, 2)]) == 1
    assert sched.pick_victim([(3, 0)]) == 3
    with pytest.raises(ValueError):
        sched.pick_victim([])
    from collections import deque
    q = deque(["x"])
    sched.requeue(q, "victim")
    assert list(q) == ["victim", "x"]


def test_prefix_and_lazy_flag_validation():
    params = _params(CFG, seed=1)
    # prefix_cache/lazy resolve paged=None to the paged layout
    eng = ServeEngine(CFG, params, slots=1, max_len=32, prefix_cache=True)
    assert eng.paged and eng.prefix_cache
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, slots=1, max_len=32, paged=False,
                    prefix_cache=True)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, slots=1, max_len=32, paged=False,
                    lazy=True)
    ssm_cfg = ModelConfig(name="prefix-ssm", arch_type="ssm", num_layers=2,
                          d_model=64, num_heads=0, num_kv_heads=0, d_ff=128,
                          ssm_state=16, ssm_heads=4, ssm_head_dim=16,
                          vocab_size=128, dtype="float32")
    with pytest.raises(ValueError, match="paged KV"):
        ServeEngine(ssm_cfg, _params(ssm_cfg, seed=4), slots=1, max_len=32,
                    prefix_cache=True)


def test_session_serve_wires_prefix_and_lazy():
    """The Session facade passes prefix_cache/lazy through to the engine
    and the served tokens match the session's own sequential generate."""
    session = Session(CFG.with_(name="prefix-session"))
    eng = session.serve(slots=2, max_len=64, page_size=8,
                        prefix_cache=True, lazy=True)
    assert eng.paged and eng.prefix_cache and eng.lazy
    rng = np.random.default_rng(7)
    system = rng.integers(0, CFG.vocab_size, size=(16,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size,
                              size=(n,)).astype(np.int32)])
        for n in (3, 5)]
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=4)
    results = eng.run()
    for i, p in enumerate(prompts):
        ref = np.asarray(session.generate(p, steps=4))[0]
        assert results[i].out == [int(t) for t in ref]
    assert eng.stats["prefix_hit_blocks"] >= 2
