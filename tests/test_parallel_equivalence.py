"""The sharded system must compute the same numbers as one device:
train_step and decode under dp x tp (+SP, +FSDP) == unsharded reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import sharding as shd
from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.train.step import init_opt_state, make_train_step
from repro.launch.mesh import make_mesh


def _mesh(data, model):
    return make_mesh((data, model), ("data", "model"))


# jax 0.4.x (no AxisType) on CPU orders the qwen3 reductions differently
# between the sharded and reference programs; the resulting near-zero-grad
# noise flips first-step adamw update signs (m_hat/sqrt(v_hat) -> +-1), a
# +-lr param jump that is an optimizer artifact, not a sharding bug.
OLD_JAX = not hasattr(jax.sharding, "AxisType")


@pytest.mark.parametrize("arch", ["qwen3-14b", "olmoe-1b-7b", "mamba2-780m"])
@pytest.mark.parametrize("strategy_kw", [
    dict(),                                  # Megatron baseline
    dict(seq_parallel=True),                 # +SP (Korthikanti)
    dict(fsdp=True),                         # +ZeRO-3
])
def test_train_step_sharded_equals_reference(arch, strategy_kw):
    if OLD_JAX and arch == "qwen3-14b":
        pytest.xfail("jax 0.4.x CPU reduction order flips first-step adamw "
                     "signs on near-zero qwen3 grads (see OLD_JAX note)")
    cfg = get_smoke(arch).with_(dtype="float32", moe_capacity_factor=16.0)
    mod = get_model(cfg)
    key = jax.random.key(0)
    params = mod.init(key, cfg)
    st = Strategy(remat=False, microbatches=1, dtype="float32",
                  **strategy_kw)
    step = make_train_step(cfg, st, lr=1e-3)
    opt = init_opt_state(params, st)
    batch = {"tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}

    p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

    mesh = _mesh(2, 4)
    with sharding_rules(mesh, st.rules(mesh)):
        psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s),
                           shd.param_pspecs(params, st, mesh))
        osh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s),
                           shd.opt_state_pspecs(opt, params, st, mesh))
        p_sh, o_sh, m_sh = jax.jit(
            step, in_shardings=(psh, osh, None),
            out_shardings=(psh, osh, None))(params, opt, batch)

    assert m_sh["loss"] == pytest.approx(float(m_ref["loss"]), abs=1e-4)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("arch", ["qwen3-14b", "zamba2-1.2b"])
def test_decode_sharded_equals_reference(arch):
    cfg = get_smoke(arch).with_(dtype="float32")
    mod = get_model(cfg)
    key = jax.random.key(1)
    params = mod.init(key, cfg)
    b, s = 4, 32
    cache = mod.init_cache(cfg, b, s)
    tok = jax.random.randint(key, (b, 1), 0, cfg.vocab_size)
    pos = jnp.asarray(0, jnp.int32)

    def step(p, c, t):
        return mod.decode_step(p, c, t, pos, cfg)

    ref_logits, _ = jax.jit(step)(params, cache, tok)

    st = Strategy(remat=False, dtype="float32")
    mesh = _mesh(2, 4)
    with sharding_rules(mesh, st.rules(mesh)):
        psh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.param_pspecs(params, st, mesh))
        csh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.cache_pspecs(cache, st, mesh, b))
        sh_logits, _ = jax.jit(step, in_shardings=(psh, csh, None)
                               )(params, cache, tok)
    np.testing.assert_allclose(np.asarray(ref_logits),
                               np.asarray(sh_logits), atol=5e-4, rtol=5e-4)


def test_microbatch_invariance():
    """Grad accumulation over microbatches == single big batch."""
    cfg = get_smoke("minitron-4b").with_(dtype="float32")
    mod = get_model(cfg)
    key = jax.random.key(2)
    params = mod.init(key, cfg)
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
    outs = {}
    for m in (1, 4):
        st = Strategy(remat=False, microbatches=m, dtype="float32")
        step = make_train_step(cfg, st, lr=1e-3)
        opt = init_opt_state(params, st)
        p2, _, met = jax.jit(step)(params, opt, batch)
        outs[m] = (p2, float(met["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], abs=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)
