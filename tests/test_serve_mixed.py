"""Unified mixed token-slot step (serve/engine.py + serve/step.py):

every engine step runs ONE program over a ``chunk_tokens`` token budget
shared between decoding slots and the prefill CHUNKS of newly admitted
requests. The contract pinned here:

  * greedy outputs are BIT-IDENTICAL to the legacy split prefill/decode
    path — dense (multi-slot), MoE (no-drop capacity), enc-dec (frames),
    prefix-cache + lazy growth, and the tp2/dp2 sharded backends;
  * a long prompt's prefill spans steps WITHOUT stalling co-resident
    decode (the short request gains a token every step);
  * trace count is bounded by (token-budget, page-bucket) shapes, not by
    prompt length — prefill_traces stays 0;
  * ``submit(..., deadline_s=)``: EDF admission, nearest-deadline
    prefill-budget priority, queued-only expiry (done=False,
    expired=True);
  * the watchdog's ``driver.abort_step`` is polled at chunk boundaries
    (``engine.abort_event``) so recovery lands in sub-step latency;
  * TTFT is stamped when the FIRST token is appended, so a request that
    finishes at admission still gets a real first-token time;
  * ``pack_token_budget`` accounting (hypothesis): every prompt token is
    allotted exactly once, no step exceeds the budget, decode — whether
    an int row count or the speculative per-slot 1 + k row sequence —
    is never displaced, dependents never run ahead of their donor's
    coverage.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.driver import AsyncDriver
from repro.serve.engine import ServeEngine
from repro.serve.parallel import ReplicaRouter, replica_meshes
from repro.serve.step import pack_token_budget

CFG = ModelConfig(name="mixed-dense", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128, dtype="float32")

# capacity_factor = E / k: the per-expert buffer holds every token even
# if the router sends ALL of them to the same expert, so no-drop dispatch
# (the mixed/split bit-identity regime) holds at any step width
MOE_CFG = ModelConfig(name="mixed-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2,
                      moe_capacity_factor=2.0, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="mixed-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab_size, size=(int(n),)).astype(np.int32)
            for n in lens]


def _serve(cfg, params, prompts, new, *, mixed, frames=None, mesh=None,
           **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    if mixed:
        kw.setdefault("chunk_tokens", 16)   # force multi-step prefill
    eng = ServeEngine(cfg, params, mesh=mesh, paged=True, mixed=mixed,
                      **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new,
                   frames=None if frames is None else frames[i])
    results = eng.run()
    return {i: list(results[i].out) for i in results}, eng


# ----------------------------------------------------- greedy bit-identity

def test_mixed_matches_split_dense_multislot():
    """Long + short prompts across 2 slots: the chunked mixed path emits
    exactly the legacy split path's greedy tokens."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(0), CFG, (5, 23, 9, 40, 6))
    split, se = _serve(CFG, params, prompts, 6, mixed=False)
    mixed, me = _serve(CFG, params, prompts, 6, mixed=True)
    assert mixed == split
    assert me.stats["prefill_traces"] == 0
    assert me.stats["prefill_chunk_tokens"] == sum(len(p) for p in prompts)
    assert se.stats["prefill_traces"] >= 1


def test_mixed_matches_split_moe():
    """No-drop MoE capacity makes expert dispatch row-independent, so the
    mixed step width cannot perturb routing: bit-identical outputs."""
    params = _params(MOE_CFG, seed=5)
    prompts = _prompts(np.random.default_rng(5), MOE_CFG, (5, 19, 8, 27))
    split, _ = _serve(MOE_CFG, params, prompts, 5, mixed=False)
    mixed, _ = _serve(MOE_CFG, params, prompts, 5, mixed=True)
    assert mixed == split


def test_mixed_matches_split_encdec():
    """Enc-dec: the encoder runs once per admission as its own program
    (encode_traces), cross-KV lands per-slot, and chunked decoder prefill
    stays bit-identical to the split path."""
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, AUDIO_CFG, (4, 17, 5, 11))
    frames = [rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    split, _ = _serve(AUDIO_CFG, params, prompts, 5, mixed=False,
                      frames=frames, max_len=32)
    mixed, me = _serve(AUDIO_CFG, params, prompts, 5, mixed=True,
                       frames=frames, max_len=32)
    assert mixed == split
    assert me.stats["encode_traces"] == 1
    assert me.stats["prefill_traces"] == 0


def test_mixed_matches_split_prefix_cache_lazy():
    """Shared system prompt + lazy growth + preemption pressure under the
    mixed step: donor/dependent chunked prefill over CoW pages is exact."""
    params = _params(CFG)
    rng = np.random.default_rng(3)
    system = rng.integers(0, CFG.vocab_size, size=(33,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size, size=(int(n),))])
        .astype(np.int32) for n in (5, 9, 3, 14)]
    kw = dict(slots=4, prefix_cache=True, lazy=True)
    split, se = _serve(CFG, params, prompts, 5, mixed=False, **kw)
    mixed, me = _serve(CFG, params, prompts, 5, mixed=True, **kw)
    assert mixed == split
    # sharing still collapses the system prompt to one physical copy
    assert me.stats["prefix_hit_blocks"] >= se.stats["prefix_hit_blocks"]


def test_mixed_matches_split_tp2_dp2():
    """The sharded backends run the same mixed program: tp2 (head-sharded
    pool) and dp2 (replica router) both match the unsharded split path."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(7), CFG, (5, 29, 9, 44))
    split, _ = _serve(CFG, params, prompts, 6, mixed=False)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(CFG, params, prompts, 6, mixed=True, mesh=mesh)
    assert tp2 == split
    assert te.stats["prefill_traces"] == 0 and \
        te.stats["decode_traces"] >= 1
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True, mixed=True, chunk_tokens=16)
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=6)
    res = router.run()
    assert {i: list(res[i].out) for i in res} == split


# --------------------------------------------------- chunked-prefill cadence

def test_long_prefill_never_stalls_decode():
    """With the budget nearly consumed by a LONG admission, the already-
    decoding short request still gains exactly one token EVERY step —
    chunked prefill shares the step instead of monopolizing it."""
    params = _params(CFG)
    rng = np.random.default_rng(11)
    short = rng.integers(0, CFG.vocab_size, size=(4,)).astype(np.int32)
    long = rng.integers(0, CFG.vocab_size, size=(40,)).astype(np.int32)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(0, short, max_new=12)
    eng.step()                                   # admit + prefill short
    eng.submit(1, long, max_new=4)
    chunk_steps = 0
    for _ in range(40):
        req0 = eng.active[0] if eng.active[0] is not None \
            else eng.finished.get(0)
        before = len(req0.out) if req0 is not None else None
        pf_before = eng.stats["prefill_chunk_tokens"]
        eng.step()
        if eng.stats["prefill_chunk_tokens"] > pf_before:
            chunk_steps += 1
            # the long prompt's chunk ran AND the short slot still decoded
            if before is not None and eng.active[0] is not None:
                assert len(eng.active[0].out) == before + 1
        if not eng.busy():
            break
    # 40 prompt tokens through a budget of 8 (minus 1 decode token):
    # prefill must have spanned several steps
    assert chunk_steps >= 5
    res = eng.run()
    assert res[0].done and res[1].done
    # parity against the split path for the same interleaving-free batch
    eng2 = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=False)
    eng2.submit(0, short, max_new=12)
    eng2.submit(1, long, max_new=4)
    res2 = eng2.run()
    assert list(res[0].out) == list(res2[0].out)
    assert list(res[1].out) == list(res2[1].out)


def test_trace_count_bounded_by_shape_not_prompt_length():
    """Many distinct prompt lengths, ONE token-budget shape: the mixed
    path's program count is bounded by page-bucket crossings (<= 3 on
    this pool) where the split path retraces prefill per bucket."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(13), CFG,
                       (4, 6, 9, 12, 17, 21, 26, 33, 40, 47))
    mixed, me = _serve(CFG, params, prompts, 4, mixed=True, slots=3)
    assert me.stats["prefill_traces"] == 0
    assert me.stats["decode_traces"] <= 3
    split, se = _serve(CFG, params, prompts, 4, mixed=False, slots=3)
    assert se.stats["prefill_traces"] >= 3      # one per prefill bucket
    assert mixed == split


# ------------------------------------------------------------ construction

def test_chunk_tokens_must_cover_slots():
    params = _params(CFG)
    with pytest.raises(ValueError, match="chunk_tokens"):
        ServeEngine(CFG, params, slots=8, max_len=64, paged=True,
                    mixed=True, chunk_tokens=4)


def test_mixed_requires_paged_layout():
    params = _params(CFG)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, slots=2, max_len=64, paged=False,
                    mixed=True)
    # dense default: mixed quietly stays off
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=False)
    assert eng.mixed is False


# --------------------------------------------------------------- deadlines

def test_deadline_edf_jumps_fifo():
    """A queued deadline request admits before earlier deadline-free
    submissions (EDF), and FIFO order still breaks ties."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(17), CFG, (5, 6, 7))
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(0, prompts[0], max_new=2)
    eng.submit(1, prompts[1], max_new=2)
    eng.submit(2, prompts[2], max_new=2, deadline_s=30.0)
    order = []
    for _ in range(300):
        eng.step()
        for rid in eng.finished:
            if rid not in order:
                order.append(rid)
        if not eng.busy():
            break
    assert order == [2, 0, 1]


def test_deadline_expired_while_queued():
    """A request whose deadline passes while it is still QUEUED finishes
    done=False, expired=True with no tokens; active requests never
    expire."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(19), CFG, (30, 6))
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(0, prompts[0], max_new=8, deadline_s=60.0)  # gets the slot
    eng.submit(1, prompts[1], max_new=4, deadline_s=0.001)
    time.sleep(0.05)
    res = eng.run()
    assert res[0].done and not res[0].expired and len(res[0].out) == 8
    assert res[1].expired and not res[1].done and res[1].out == []
    assert eng.stats["expired"] == 1


def test_deadline_submit_validation():
    params = _params(CFG)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True)
    with pytest.raises(ValueError, match="deadline"):
        eng.submit(0, np.arange(4, dtype=np.int32), max_new=2,
                   deadline_s=0.0)


def test_nearest_deadline_gets_prefill_budget_first():
    """Two long prompts admitted together: the tight budget drains the
    NEARER deadline's prompt first, so it emits its first token first."""
    params = _params(CFG)
    rng = np.random.default_rng(23)
    prompts = _prompts(rng, CFG, (32, 32))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(0, prompts[0], max_new=3)                  # no deadline
    eng.submit(1, prompts[1], max_new=3, deadline_s=60.0)
    first = {}
    for step in range(300):
        eng.step()
        for s in range(eng.slots):
            req = eng.active[s]
            if req is not None and req.out and req.rid not in first:
                first[req.rid] = step
        for rid, req in eng.finished.items():
            if req.out and rid not in first:
                first[rid] = step
        if not eng.busy():
            break
    assert first[1] < first[0], first


# ------------------------------------------------- watchdog chunk boundary

def test_abort_event_yields_at_chunk_boundary():
    """With ``engine.abort_event`` set, a mixed step returns WITHOUT
    launching a program or advancing any prefill cursor — the sub-step
    cancellation point the watchdog's recovery relies on — and stepping
    resumes cleanly once it clears."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(29), CFG, (40,))
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(0, prompts[0], max_new=4)
    eng.step()                                   # first prefill chunk
    pf = eng.stats["prefill_chunk_tokens"]
    assert pf > 0
    ev = threading.Event()
    eng.abort_event = ev
    ev.set()
    eng.step()                                   # aborted: no work
    assert eng.stats["prefill_chunk_tokens"] == pf
    assert eng.stats["decode_tokens"] == 0
    ev.clear()
    res = eng.run()
    assert res[0].done and len(res[0].out) == 4


def test_driver_wires_abort_event_and_recovers_mid_prefill():
    """AsyncDriver hands its ``abort_step`` to every mixed engine at
    construction; an injected stall while a LONG prompt is mid-prefill
    fires the watchdog, the chunk-boundary poll yields in sub-stall
    latency, and the requeued request still completes with parity."""
    params = _params(CFG)
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, CFG.vocab_size, size=(40,)).astype(np.int32)
    base, _ = _serve(CFG, params, [prompt], 6, mixed=False, slots=1)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    eng.submit(100, prompt[:6], max_new=2)       # warm the program
    eng.run()

    calls = {"n": 0}
    yielded = {"dt": None}

    def step_fn(drv):
        calls["n"] += 1
        if calls["n"] == 3:                      # rid 0 is mid-prefill
            t0 = time.monotonic()
            # a stalled chunk loop: poll the SAME event the engine polls
            # at every chunk boundary, never longer than one chunk apart
            while not drv.abort_step.is_set() and \
                    time.monotonic() - t0 < 20.0:
                time.sleep(0.02)
            yielded["dt"] = time.monotonic() - t0
            return
        drv.engine.step()

    drv = AsyncDriver(eng, watchdog_timeout=0.25, step_fn=step_fn,
                      start=False)
    assert eng.abort_event is drv.abort_step
    stream = drv.submit(prompt, max_new=6, rid=0)
    drv.start()
    rec = stream.result(timeout=60.0)
    drv.stop(drain=True)
    assert rec.done and list(rec.out) == base[0]
    assert drv.metrics.watchdog_fired.value >= 1
    assert eng.stats["preemptions"] >= 1
    # sub-step recovery: the stalled "chunk" yielded within ~a timeout,
    # nowhere near the 20s a full uncancellable step would cost
    assert yielded["dt"] is not None and yielded["dt"] < 5.0
    assert not drv.abort_step.is_set()


# ------------------------------------------------------------------- TTFT

def test_ttft_stamped_for_finish_at_admission():
    """A request that completes in its admission step (max_new=1) still
    records a real first-token time: TTFT comes from the token-append
    stamp, not from whenever the drain loop notices completion."""
    params = _params(CFG)
    prompt = np.arange(5, dtype=np.int32)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8)
    drv = AsyncDriver(eng, start=False)
    stream = drv.submit(prompt, max_new=1)
    drv.start()
    rec = stream.result(timeout=60.0)
    drv.stop(drain=False)
    assert rec.done and len(rec.out) == 1
    assert rec.first_tok_t is not None
    assert drv.metrics.ttft.count == 1
    [p50] = drv.metrics.ttft.quantiles([0.5])
    assert 0.0 <= p50 < 60.0
    assert stream.first_token_s is not None


# ----------------------------------------------- token-budget accounting

def test_pack_token_budget_rejects_oversubscribed_decode():
    with pytest.raises(ValueError, match="token budget"):
        pack_token_budget(4, 5, [])


def test_pack_token_budget_per_slot_row_counts():
    """Speculative decode reserves 1 + k rows per decoding slot: a
    per-slot row-count sequence is exactly equivalent to its sum, and
    oversubscription raises the same budget error."""
    items = [{"slot": 0, "cursor": 0, "n": 12, "dep": None},
             {"slot": 1, "cursor": 3, "n": 9, "dep": None}]
    assert pack_token_budget(16, [5, 3], [dict(i) for i in items]) == \
        pack_token_budget(16, 8, [dict(i) for i in items])
    # the whole budget may go to draft rows (no prefill room left)
    assert pack_token_budget(8, [5, 3], [dict(i) for i in items]) == []
    with pytest.raises(ValueError, match="token budget"):
        pack_token_budget(8, [5, 4], [])


# hypothesis comes from the [test] extra; a bare env falls back to a
# fixed seed sweep of the same generator so the module stays green
try:
    from hypothesis import given, settings, strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _rows_total(n_decode):
    """Reserved decode rows: int, or a per-slot row-count sequence (the
    speculative 1 + k rows per slot hook)."""
    return sum(n_decode) if isinstance(n_decode, list) else n_decode


def _random_case(rng):
    """One random budget-accounting case (mirrors the hypothesis
    strategy, driven by numpy when hypothesis is absent)."""
    budget = int(rng.integers(1, 65))
    n_decode = int(rng.integers(0, budget))
    if n_decode and rng.random() < 0.5:
        # same reservation expressed per slot (draft rows included)
        m = int(rng.integers(1, min(n_decode, 4) + 1))
        rows = [1] * m
        for _ in range(n_decode - m):
            rows[int(rng.integers(0, m))] += 1
        n_decode = rows
    items = []
    for i in range(int(rng.integers(0, 7))):
        n = int(rng.integers(1, 61))
        cursor = int(rng.integers(0, n))
        dep = None
        if items and rng.random() < 0.5:
            donor = int(rng.integers(0, len(items)))
            dep = (donor, int(rng.integers(1, items[donor]["n"] + 1)))
        items.append({"slot": i, "cursor": cursor, "n": n, "dep": dep})
    return budget, n_decode, items


def _check_single_step(case):
    """One pack call: decode reserved first, contiguous per-slot chunks,
    dependents never scheduled ahead of their donor's planned coverage."""
    budget, n_decode, items = case
    allot = pack_token_budget(budget, n_decode,
                              [dict(it) for it in items])
    by_slot = {}
    for s, start, count in allot:
        assert count >= 1
        assert s not in by_slot               # one chunk per slot per step
        by_slot[s] = (start, count)
    # decode (and per-slot draft rows) reserved first: prefill never
    # displaces a reserved row
    assert sum(c for _, _, c in allot) <= budget - _rows_total(n_decode)
    planned = {it["slot"]: it["cursor"] for it in items}
    for it in items:
        if it["slot"] in by_slot:
            start, count = by_slot[it["slot"]]
            assert start == it["cursor"]      # chunks are contiguous
            assert start + count <= it["n"]
            if it["dep"] is not None:
                donor, needed = it["dep"]
                assert planned.get(donor, needed) >= needed
            planned[it["slot"]] = start + count


def _check_drains_exactly_once(case):
    """Driving pack_token_budget to completion allots every remaining
    prompt position exactly once, never exceeding the budget per step.
    Completed donors drop out of the item list, which unblocks their
    dependents exactly as the engine's dep-clearing pass does."""
    budget, n_decode, items = case
    seen = {it["slot"]: set() for it in items}
    remaining = [dict(it) for it in items]
    for _ in range(10_000):
        live = [it for it in remaining if it["cursor"] < it["n"]]
        if not live:
            break
        allot = pack_token_budget(budget, n_decode, live)
        assert sum(c for _, _, c in allot) <= budget - _rows_total(n_decode)
        by_slot = {s: (start, count) for s, start, count in allot}
        for it in live:
            if it["slot"] in by_slot:
                start, count = by_slot[it["slot"]]
                assert start == it["cursor"]
                positions = set(range(start, start + count))
                assert not positions & seen[it["slot"]]   # exactly-once
                seen[it["slot"]] |= positions
                it["cursor"] += count
    assert all(it["cursor"] == it["n"] for it in remaining)
    for it in items:
        assert seen[it["slot"]] == set(range(it["cursor"], it["n"]))


if HAVE_HYPOTHESIS:
    @hst.composite
    def _budget_case(draw):
        budget = draw(hst.integers(min_value=1, max_value=64))
        n_decode = draw(hst.integers(min_value=0, max_value=budget - 1))
        if n_decode and draw(hst.booleans()):
            # per-slot row counts (speculative draft rows), same total
            m = draw(hst.integers(min_value=1,
                                  max_value=min(n_decode, 4)))
            rows = [1] * m
            for _ in range(n_decode - m):
                rows[draw(hst.integers(min_value=0,
                                       max_value=m - 1))] += 1
            n_decode = rows
        items = []
        for i in range(draw(hst.integers(min_value=0, max_value=6))):
            n = draw(hst.integers(min_value=1, max_value=60))
            cursor = draw(hst.integers(min_value=0, max_value=n - 1))
            dep = None
            if items and draw(hst.booleans()):
                donor = draw(hst.integers(min_value=0,
                                          max_value=len(items) - 1))
                dep = (donor, draw(hst.integers(
                    min_value=1, max_value=items[donor]["n"])))
            items.append({"slot": i, "cursor": cursor, "n": n, "dep": dep})
        return budget, n_decode, items

    @given(_budget_case())
    @settings(max_examples=200, deadline=None)
    def test_pack_token_budget_properties(case):
        _check_single_step(case)

    @given(_budget_case())
    @settings(max_examples=100, deadline=None)
    def test_pack_token_budget_drains_every_token_exactly_once(case):
        _check_drains_exactly_once(case)
else:
    def test_pack_token_budget_properties():
        rng = np.random.default_rng(0)
        for _ in range(200):
            _check_single_step(_random_case(rng))

    def test_pack_token_budget_drains_every_token_exactly_once():
        rng = np.random.default_rng(1)
        for _ in range(100):
            _check_drains_exactly_once(_random_case(rng))
