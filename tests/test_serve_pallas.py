"""Pallas fused paged-attention decode (kernels/paged_attention.py +
``attn_backend="pallas"``):

The flash-decoding kernel walks the page table directly — block-per-page
grid, online softmax across the page axis, pool indexed through a
scalar-prefetched BlockSpec index map — so contiguous per-row KV is
never materialized. It must be a drop-in for the gather backend: greedy
serve outputs token-identical on dense/MoE/enc-dec/prefix+lazy/tp2,
exactly ONE decode trace per page bucket (identical retrace cadence),
``kv_len = pos + 1`` masking null-page-0 / reservation tails / ragged
last pages, GQA q-heads folded to their kv head in-kernel. Kernel-level
parity runs against the gather reference on adversarial tables
(permuted, fragmented, null-padded). The HLO test pins the point of the
exercise: the gather backend's ``(B, P*page_size, Hkv, D)`` intermediate
is ABSENT from the pallas decode program.

Also hosts the non-hypothesis flash_attention regressions (ragged
lengths pad-and-mask, native-GQA forward/backward) — tests/test_kernels
is skipped wholesale when hypothesis is missing, these must not be.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models import get_model, layers
from repro.serve.engine import ServeEngine
from repro.serve.parallel import ReplicaRouter, replica_meshes

CFG = ModelConfig(name="pal-dense", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

MOE_CFG = ModelConfig(name="pal-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="pal-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _serve(cfg, params, prompts, new, *, frames=None, mesh=None, slots=2,
           max_len=64, **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len, mesh=mesh,
                      paged=True, **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new,
                   frames=None if frames is None else frames[i])
    results = eng.run()
    return {i: results[i].out for i in results}, eng


# ------------------------------------------------------- kernel parity

def _rand_paged(rng, *, b, width, n_pages, page_size, hq, hkv, d):
    """A pool + adversarial tables: page ids permuted and fragmented
    (interleaved across rows, non-contiguous, nowhere in logical order),
    page 0 reserved as the null page, per-row cursors landing mid-page
    so the last page is ragged."""
    kp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page_size, hkv, d)),
                     jnp.float32)
    perm = rng.permutation(np.arange(1, n_pages))  # never the null page
    tab = np.zeros((b, width), np.int32)
    pos = np.zeros((b,), np.int32)
    k = 0
    for r in range(b):
        # ragged: row r holds r+1 pages, cursor inside the last one
        n_blk = r % width + 1
        tab[r, :n_blk] = perm[k:k + n_blk]
        k += n_blk
        pos[r] = (n_blk - 1) * page_size + int(rng.integers(0, page_size))
    return kp, vp, jnp.asarray(tab), jnp.asarray(pos)


def test_kernel_matches_gather_on_fragmented_tables():
    """GQA decode over permuted/fragmented tables with ragged last pages
    and null-page tails: the fused kernel matches the gather reference
    for every row."""
    rng = np.random.default_rng(0)
    kp, vp, tab, pos = _rand_paged(rng, b=4, width=4, n_pages=16,
                                   page_size=8, hq=8, hkv=2, d=32)
    q = jnp.asarray(rng.standard_normal((4, 1, 8, 32)), jnp.float32)
    want = layers.paged_attention(q, kp, vp, tab, pos, backend="gather")
    got = layers.paged_attention(q, kp, vp, tab, pos, backend="pallas")
    assert got.shape == want.shape == (4, 1, 8, 32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_kernel_masks_null_pages_exactly():
    """Garbage in the pool behind null-page-0 table entries and past
    each cursor must not leak: poisoning page 0 and all unreferenced
    pages with huge values changes nothing."""
    rng = np.random.default_rng(1)
    kp, vp, tab, pos = _rand_paged(rng, b=3, width=4, n_pages=16,
                                   page_size=4, hq=4, hkv=4, d=16)
    q = jnp.asarray(rng.standard_normal((3, 1, 4, 16)), jnp.float32)
    clean = layers.paged_attention(q, kp, vp, tab, pos, backend="pallas")
    live = np.unique(np.asarray(tab))
    poison = np.setdiff1d(np.arange(16), live[live > 0])
    kp = kp.at[poison].set(1e9)
    vp = vp.at[poison].set(1e9)
    # ...and garbage INSIDE referenced pages past the cursor (ragged tail)
    for r in range(3):
        last = int(np.asarray(tab)[r, int(pos[r]) // 4])
        kp = kp.at[last, int(pos[r]) % 4 + 1:].set(1e9)
        vp = vp.at[last, int(pos[r]) % 4 + 1:].set(1e9)
    dirty = layers.paged_attention(q, kp, vp, tab, pos, backend="pallas")
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))
    want = layers.paged_attention(q, kp, vp, tab, pos, backend="gather")
    np.testing.assert_allclose(dirty, want, atol=2e-5, rtol=2e-5)


def test_kernel_hlo_has_no_gathered_kv():
    """The point of the kernel: the gather backend materializes a
    ``(B, P*page_size, Hkv, D)`` contiguous-KV intermediate per call;
    the pallas program must not."""
    b, width, page_size, hkv, d = 2, 4, 8, 2, 32
    kp = jnp.zeros((16, page_size, hkv, d), jnp.float32)
    q = jnp.zeros((b, 1, 2 * hkv, d), jnp.float32)
    tab = jnp.zeros((b, width), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    gathered = f"f32[{b},{width * page_size},{hkv},{d}]"

    def run(backend):
        fn = lambda *a: layers.paged_attention(*a, backend=backend)
        return jax.jit(fn).lower(q, kp, kp, tab, pos) \
            .compile().as_text()
    assert gathered in run("gather")        # the baseline really does it
    assert gathered not in run("pallas")    # the kernel never does


# -------------------------------------------------------- serve parity

def test_pallas_dense_matches_gather():
    """Greedy dense serve is bit-identical across backends, one decode
    trace each, and the backend is observable in stats."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(0), CFG, (5, 7, 6, 8, 5))
    base, be = _serve(CFG, params, prompts, 6, attn_backend="gather")
    pal, pe = _serve(CFG, params, prompts, 6, attn_backend="pallas")
    assert pal == base
    assert be.stats["decode_traces"] == pe.stats["decode_traces"] == 1
    assert be.stats["decode_backend"] == "gather"
    assert pe.stats["decode_backend"] == "pallas"
    pe.reset_stats()
    assert pe.stats["decode_backend"] == "pallas"   # identity survives


def test_pallas_moe_matches_gather():
    params = _params(MOE_CFG, seed=5)
    prompts = _prompts(np.random.default_rng(6), MOE_CFG, (5, 8, 6))
    kw = dict(slots=1, max_len=32, page_size=8)
    base, _ = _serve(MOE_CFG, params, prompts, 4, **kw)
    pal, pe = _serve(MOE_CFG, params, prompts, 4, attn_backend="pallas",
                     **kw)
    assert pal == base
    assert pe.stats["decode_traces"] == 1


def test_pallas_encdec_matches_gather():
    """Enc-dec: the kernel runs on the paged self-attention KV while the
    per-slot cross-KV path is untouched."""
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, AUDIO_CFG, (4, 7, 5))
    frames = [rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    base, _ = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                     max_len=32)
    pal, pe = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                     max_len=32, attn_backend="pallas")
    assert pal == base
    assert pe.stats["decode_traces"] == 1


def test_pallas_prefix_cache_lazy_matches_gather():
    """CoW sharing + lazy growth only rewrite table VALUES — the kernel
    is as layout-blind as the gather, with the same prefix hit counts."""
    params = _params(CFG)
    rng = np.random.default_rng(4)
    sys_p = rng.integers(0, CFG.vocab_size, size=(16,))
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, CFG.vocab_size, size=(5,))]
    ).astype(np.int32) for _ in range(4)]
    kw = dict(prefix_cache=True, lazy=True)
    base, be = _serve(CFG, params, prompts, 6, **kw)
    pal, pe = _serve(CFG, params, prompts, 6, attn_backend="pallas", **kw)
    assert pal == base
    assert pe.stats["decode_traces"] == 1
    assert pe.stats["prefix_hit_blocks"] > 0
    assert pe.stats["prefix_hit_blocks"] == be.stats["prefix_hit_blocks"]


def test_pallas_tp2_matches_gather_tp1():
    """The kernel composes with the head-sharded pool: each shard's grid
    covers its own Hkv/tp heads, outputs stay bit-identical to the
    unsharded gather engine."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(3), CFG, (5, 7, 6, 8))
    base, be = _serve(CFG, params, prompts, 6)
    [mesh] = replica_meshes(1, 2)
    pal, pe = _serve(CFG, params, prompts, 6, mesh=mesh,
                     attn_backend="pallas")
    assert pal == base
    assert pe.tp == 2
    assert pe.stats["decode_traces"] == 1
    assert pe.per_device_kv_bytes() * 2 == be.per_device_kv_bytes()


def test_pallas_bucket_retrace_cadence_matches_gather():
    """Shapes depend only on the bucketed table width: the pallas
    program retraces exactly when the gather one does — when a LONGER
    request pushes the worst-case reservation over a power-of-two page
    bucket — and never mid-decode."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(9), CFG, (5, 6))

    def waves(backend):
        eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                          page_size=4, attn_backend=backend)
        eng.submit(0, prompts[0], max_new=4)     # 9 tok -> bucket 4
        out = {0: eng.run()[0].out}
        first = eng.stats["decode_traces"]
        eng.submit(1, prompts[1], max_new=30)    # 36 tok -> bucket 16
        out[1] = eng.run()[1].out
        return out, first, eng.stats["decode_traces"]

    base, bfirst, btotal = waves("gather")
    pal, pfirst, ptotal = waves("pallas")
    assert pal == base
    assert (bfirst, btotal) == (pfirst, ptotal) == (1, 2)


def test_pallas_dp_router_aggregates_backend():
    """ReplicaRouter passes attn_backend through and its summed stats
    carry the identity field instead of crashing on the string."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(5), CFG, (5, 7, 6, 8))
    base, _ = _serve(CFG, params, prompts, 6)
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True, attn_backend="pallas")
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=6)
    res = router.run()
    assert {i: res[i].out for i in res} == base
    st = router.stats
    assert st["decode_backend"] == "pallas"
    assert all(r["decode_traces"] == 1 for r in st["replicas"])


def test_attn_backend_validation():
    params = _params(CFG)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(CFG, params, slots=2, max_len=64, paged=False,
                    attn_backend="pallas")
    with pytest.raises(ValueError, match="gather"):
        ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                    attn_backend="triton")


# -------------------------------- flash_attention regressions (no
# hypothesis — tests/test_kernels is importorskip'd on it wholesale)

def test_flash_ragged_lengths_match_ref():
    """Sequence lengths that don't divide the block sizes used to trip a
    bare AssertionError; the wrapper now pads and masks, so any shape
    matches the dense reference."""
    rng = np.random.default_rng(0)
    for s, t in ((192, 192), (100, 150), (7, 130)):
        q = jnp.asarray(rng.standard_normal((2, s, 4, 64)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, t, 4, 64)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, t, 4, 64)), jnp.float32)
        causal = s == t
        out = ops.flash_attention(q, k, v, causal=causal)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        assert out.shape == (2, s, 4, 64)
        np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_gqa_native_forward_and_grads():
    """GQA runs without pre-repeating K/V: the kv row folds into the
    kernel's index map, and the backward group-sums dk/dv back to Hkv.
    Both must match autodiff through the repeated dense reference."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 128, 2, 64)), jnp.float32)

    def loss(fn, rep):
        return lambda q, k, v: fn(
            q, jnp.repeat(k, rep, 2) if rep > 1 else k,
            jnp.repeat(v, rep, 2) if rep > 1 else v, causal=True).sum()

    out = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, jnp.repeat(k, 4, 2),
                                   jnp.repeat(v, 4, 2), causal=True)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)
    grads = jax.grad(loss(ops.flash_attention, 1), (0, 1, 2))(q, k, v)
    wants = jax.grad(loss(ref.flash_attention_ref, 4), (0, 1, 2))(q, k, v)
    for g, w in zip(grads, wants):
        assert g.shape == w.shape
        np.testing.assert_allclose(g, w, atol=2e-4, rtol=2e-4)

    with pytest.raises(ValueError, match="multiple"):
        ops.flash_attention(q[:, :, :5], k, v)   # 5 % 2 != 0
