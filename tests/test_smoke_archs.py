"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned arch runs one forward AND one train step on CPU; output shapes and
finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.train.step import init_opt_state, make_train_step


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.has_encoder:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_ctx, cfg.d_model))
    if cfg.cross_attn_every > 0:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.num_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    mod = get_model(cfg)
    key = jax.random.key(0)
    params = mod.init(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = jax.jit(lambda p, b: mod.forward(p, b, cfg))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_one_train_step(arch):
    cfg = get_smoke(arch)
    mod = get_model(cfg)
    key = jax.random.key(1)
    params = mod.init(key, cfg)
    st = Strategy(remat=True, microbatches=2, dtype=cfg.dtype)
    step = make_train_step(cfg, st, lr=1e-3)
    opt = init_opt_state(params, st)
    batch = _batch(cfg, key, b=4, s=32)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = max(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step_shapes(arch):
    cfg = get_smoke(arch)
    mod = get_model(cfg)
    key = jax.random.key(2)
    params = mod.init(key, cfg)
    b = 2
    cache = mod.init_cache(cfg, b, 64)
    tok = jnp.zeros((b, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: mod.decode_step(p, c, t, jnp.asarray(0, jnp.int32),
                                        cfg))(params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
