"""MoE block: router normalisation, capacity semantics, dense-equivalence,
load-balance loss properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.configs import get_smoke
from repro.models import moe as moe_lib


def _cfg(**kw):
    return get_smoke("olmoe-1b-7b").with_(dtype="float32", **kw)


def test_router_gates_normalised():
    cfg = _cfg()
    key = jax.random.key(0)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (64, cfg.d_model))
    gates, idx, aux = moe_lib.router_topk(p["router"], x, cfg)
    np.testing.assert_allclose(gates.sum(-1), 1.0, atol=1e-6)
    assert gates.shape == (64, cfg.experts_per_token)
    assert int(idx.max()) < cfg.num_experts
    # aux loss >= 1 (equality iff perfectly balanced), Shazeer-style
    assert float(aux) >= 0.99


def test_moe_single_expert_equals_dense_mlp():
    """E=1, k=1 MoE must equal the dense gated MLP with the same weights."""
    from repro.models.layers import mlp
    cfg = _cfg(num_experts=1, experts_per_token=1, moe_capacity_factor=2.0)
    key = jax.random.key(1)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.5
    out, aux = moe_lib.moe_ffn(p, x, cfg)
    dense = mlp({"w_gate": p["w_gate"][0], "w_up": p["w_up"][0],
                 "w_down": p["w_down"][0]}, x)
    np.testing.assert_allclose(out, dense, atol=1e-5, rtol=1e-5)


def test_capacity_drops_tokens():
    """With a tiny capacity factor some tokens must pass through unrouted
    (output contribution 0 for dropped slots)."""
    cfg = _cfg(moe_capacity_factor=0.05)
    key = jax.random.key(2)
    p = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(key, (4, 64, cfg.d_model))
    out_small, _ = moe_lib.moe_ffn(p, x, cfg)
    cfg_big = _cfg(moe_capacity_factor=16.0)
    out_big, _ = moe_lib.moe_ffn(p, x, cfg_big)
    assert float(jnp.abs(out_small - out_big).max()) > 1e-4
    # dropped tokens produce smaller outputs on average
    assert float(jnp.abs(out_small).mean()) < float(jnp.abs(out_big).mean())


@settings(max_examples=8, deadline=None)
@given(seed=hst.integers(0, 2**30))
def test_moe_permutation_equivariance(seed):
    """Permuting tokens permutes outputs (no positional leakage through
    dispatch) — requires no capacity drops to hold exactly."""
    cfg = _cfg(moe_capacity_factor=16.0)
    key = jax.random.key(seed)
    p = moe_lib.init_moe(key, cfg)
    t = 32
    x = jax.random.normal(key, (1, t, cfg.d_model))
    perm = jax.random.permutation(jax.random.key(seed + 1), t)
    out, _ = moe_lib.moe_ffn(p, x, cfg)
    out_p, _ = moe_lib.moe_ffn(p, x[:, perm], cfg)
    np.testing.assert_allclose(out[:, perm], out_p, atol=2e-5, rtol=2e-5)


def test_capacity_rounding():
    cfg = _cfg()
    c = moe_lib.capacity(1024, cfg)
    assert c % 8 == 0
    assert c >= 1024 * cfg.experts_per_token / cfg.num_experts
