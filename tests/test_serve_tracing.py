"""PR 10 observability layer (serve/tracing.py + its weave): request
span trees whose token counters match the streamed output exactly, step
phase laps covering >= 95% of step wall time, bounded flight-recorder
rings, Chrome/Perfetto trace_event export (monotonic per-lane
timestamps, dp2 merge with one pid lane per replica and no id
collisions), the ``serve_step_phase_seconds{phase=...}`` histogram fed
by the driver drain, render-vs-observe hammer on every metric class, and
the lock-free ``/healthz`` + ``/debug/*`` endpoints answering while a
stalled step holds the driver lock.
"""
import json
import threading
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.driver import AsyncDriver
from repro.serve.engine import ServeEngine
from repro.serve.metrics import (Histogram, LabeledHistogram,
                                 MetricsRegistry, ServeMetrics)
from repro.serve.parallel import ReplicaRouter
from repro.serve.server import ServeHTTPServer
from repro.serve.tracing import (LEVEL_DETAIL, LEVEL_OFF, NULL_STEP,
                                 StepTrace, Tracer, chrome_trace,
                                 phase_coverage)

CFG = ModelConfig(name="trace-dense", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab_size, size=(int(n),)).astype(np.int32)
            for n in lens]


def _run_engine(eng, prompts, max_new=6):
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=max_new)
    return eng.run()


# ------------------------------------------------------------- unit level

def test_step_trace_laps_partition_wall_time():
    st = StepTrace(7)
    time.sleep(0.01)
    st.lap("pack")
    time.sleep(0.01)
    st.lap("dispatch")
    st.lap("pack")                      # repeats accumulate
    tr = Tracer()
    tr.end_step(st, produced=3)
    [rec] = tr.flight()["steps"]
    assert rec["step_id"] == 7 and rec["produced"] == 3
    assert set(rec["phases"]) == {"pack", "dispatch"}
    # laps partition [t0, end_step): coverage is ~100% of dur
    assert sum(rec["phases"].values()) <= rec["dur"]
    assert sum(rec["phases"].values()) >= 0.95 * rec["dur"]


def test_tracer_rings_are_bounded():
    tr = Tracer(max_steps=4, max_requests=3, max_events=5)
    for i in range(10):
        tr.end_step(tr.begin_step(i), produced=0)
    assert [r["step_id"] for r in tr.flight()["steps"]] == [6, 7, 8, 9]
    for rid in range(9):
        tr.req_event(rid, "submitted")
        for _ in range(10):             # overflow the event cap
            tr.req_event(rid, "noise")
        tr.finish_request(rid, "completed")
    snap = tr.flight()
    assert len(snap["finished_requests"]) == 3     # ring, newest kept
    assert snap["finished_requests"][-1]["rid"] == 8
    assert all(r["dropped_events"] > 0 for r in snap["finished_requests"])
    # pending phase queue drains once, then is empty
    assert len(tr.drain_phases()) == 4
    assert tr.drain_phases() == []


def test_level0_is_off_and_null_step_is_shared():
    tr = Tracer(level=LEVEL_OFF)
    assert not tr.enabled
    assert tr.begin_step(0) is NULL_STEP
    NULL_STEP.lap("x")
    NULL_STEP.note_decode(0, 0, 1)
    NULL_STEP.note_chunk(0, 0, 0, 4)   # all no-ops
    tr.end_step(NULL_STEP, produced=5)
    tr.req_event(0, "submitted")
    tr.req_tokens(0, 3)
    tr.finish_request(0, "completed")
    snap = tr.flight()
    assert snap["steps"] == [] and snap["live_requests"] == [] \
        and snap["finished_requests"] == []


# -------------------------------------------------------- engine weaving

def test_span_tree_matches_streamed_token_count():
    """The acceptance pin: RequestTrace.tokens == len(request.out) for
    every request, across chunked prefill AND speculative decode."""
    from repro.serve.speculative import SpecConfig
    params = _params(CFG)
    rng = np.random.default_rng(0)
    # long/short mix forces multi-step chunked prefill; a repeated motif
    # makes the ngram drafter land multi-token accepts
    motif = rng.integers(0, CFG.vocab_size, size=(5,))
    prompts = _prompts(rng, CFG, (40, 6, 23)) + \
        [np.tile(motif, 6).astype(np.int32)]
    eng = ServeEngine(CFG, params, slots=2, max_len=96, paged=True,
                      mixed=True, chunk_tokens=16,
                      spec=SpecConfig(k=4, drafter="ngram"))
    results = _run_engine(eng, prompts, max_new=8)
    assert len(results) == len(prompts)
    for rid, req in results.items():
        tree = eng.tracer.request_trace(rid)
        assert tree is not None and tree["done"]
        assert tree["outcome"] == "completed"
        assert tree["tokens"] == len(req.out)
        kinds = [e["kind"] for e in tree["events"]]
        assert kinds[0] == "submitted"
        assert "admitted" in kinds and "first_token" in kinds
        assert kinds[-1] == "completed"
        assert kinds.index("admitted") < kinds.index("first_token")
    # chunked prefill is accounted token-exactly too (no prefix cache:
    # every prompt position goes through exactly one chunk)
    for rid, p in enumerate(prompts):
        assert eng.tracer.request_trace(rid)["chunk_tokens"] == len(p)


def test_phase_coverage_and_step_accounting():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(1), CFG, (30, 5, 12))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=16)
    _run_engine(eng, prompts, max_new=6)
    cov = phase_coverage([eng.tracer])
    assert cov >= 0.95, cov             # the acceptance bound
    steps = eng.tracer.flight()["steps"]
    assert steps, "no step records"
    # every step record's phases sit inside its duration and the mixed
    # phase vocabulary is what the engine laps
    for rec in steps:
        assert sum(rec["phases"].values()) <= rec["dur"] + 1e-9
        assert set(rec["phases"]) <= {"bookkeeping", "draft", "pack",
                                      "dispatch", "sync"}
    # produced totals across the ring match the engine counter
    assert sum(r["produced"] for r in steps) == eng.stats["decode_tokens"]


def test_legacy_path_is_traced_too():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(2), CFG, (9, 5))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=False)
    results = _run_engine(eng, prompts, max_new=4)
    for rid, req in results.items():
        tree = eng.tracer.request_trace(rid)
        assert tree["tokens"] == len(req.out) and tree["done"]
    recs = eng.tracer.flight()["steps"]
    assert recs and all("dispatch" in r["phases"] for r in recs)
    assert phase_coverage([eng.tracer]) >= 0.95


def test_trace_level_2_adds_detail_and_level_0_adds_nothing():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(3), CFG, (25, 6))
    eng2 = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                       mixed=True, chunk_tokens=16,
                       trace_level=LEVEL_DETAIL)
    res2 = _run_engine(eng2, prompts, max_new=4)
    kinds = [e["kind"]
             for e in eng2.tracer.request_trace(0)["events"]]
    assert "prefill_chunk" in kinds and "decode" in kinds
    eng0 = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                       mixed=True, chunk_tokens=16, trace_level=0)
    res0 = _run_engine(eng0, prompts, max_new=4)
    assert eng0.tracer.flight()["steps"] == []
    assert eng0.tracer.request_trace(0) is None
    # tracing level never changes the tokens
    assert {r: list(v.out) for r, v in res0.items()} \
        == {r: list(v.out) for r, v in res2.items()}


# ------------------------------------------------------------ export shape

def _lane_ts_monotonic(events):
    lanes = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        lane = (ev["pid"], ev["tid"], ev.get("cat"))
        assert ev["ts"] >= lanes.get(lane, float("-inf")), lane
        lanes[lane] = ev["ts"]
    return lanes


def test_chrome_export_is_valid_trace_event_json(tmp_path):
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(4), CFG, (30, 5, 14))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=16)
    _run_engine(eng, prompts, max_new=5)
    path = tmp_path / "trace.json"
    obj = eng.export_trace(str(path))
    disk = json.loads(path.read_text())
    assert disk == obj
    evs = disk["traceEvents"]
    assert isinstance(evs, list) and evs
    for ev in evs:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert "ts" in ev and "dur" in ev and ev["dur"] >= 0
    lanes = _lane_ts_monotonic(evs)
    assert lanes, "no complete events"
    # the step lane exists and slot lanes carry named work spans
    names = {e["name"] for e in evs}
    assert any(n.startswith("step ") for n in names)
    assert any(n.startswith(("decode r", "prefill r")) for n in names)
    # metadata rows label every lane that has spans
    assert {e["args"]["name"] for e in evs if e["name"] == "thread_name"} \
        >= {"engine steps", "slot 0"}
    # request span trees ride in otherData
    assert set(disk["otherData"]["requests"]) == {"0"}
    assert {r["rid"] for r in disk["otherData"]["requests"]["0"]} \
        == set(range(len(prompts)))


def test_dp2_trace_merge_has_both_replica_lanes(tmp_path):
    params = _params(CFG)
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, CFG, (18, 7, 22, 9, 13, 6))
    router = ReplicaRouter(CFG, params, dp=2, tp=1, slots=2, max_len=64,
                           paged=True, mixed=True, chunk_tokens=16)
    assert [t.replica for t in router.tracers] == [0, 1]
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=4)
    router.run()
    path = tmp_path / "dp2.json"
    obj = router.export_trace(str(path))
    evs = json.loads(path.read_text())["traceEvents"]
    pids = {e["pid"] for e in evs if e["ph"] == "X"}
    assert pids == {0, 1}, "both replica lanes must appear"
    _lane_ts_monotonic(evs)
    # no rid collisions ACROSS lanes: each request's spans live only in
    # its home replica's pid
    for rid in range(len(prompts)):
        home = router.replica_of(rid)
        owning = {e["pid"] for e in evs
                  if e["ph"] == "X" and e.get("args", {}).get("rid") == rid}
        assert owning == {home}
    # per-replica step ids overlap (both start at 0) but stay in
    # distinct pid lanes — that is the collision-avoidance contract
    assert set(obj["otherData"]["requests"]) == {"0", "1"}
    flight = router.flight()
    assert [f["replica"] for f in flight["replicas"]] == [0, 1]


# ------------------------------------------------- driver + metrics drain

def test_driver_feeds_phase_histogram_and_render():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(6), CFG, (20, 6))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=16)
    drv = AsyncDriver(eng, start=False)
    streams = [drv.submit(p, max_new=4, rid=i)
               for i, p in enumerate(prompts)]
    drv.start()
    assert drv.join(timeout=120.0)
    drv.stop(drain=False)
    for s in streams:
        assert s.result(timeout=0.0).done
    hist = drv.metrics.step_phase
    assert {"dispatch", "pack", "sync"} <= set(hist.labels())
    assert hist.child("dispatch").count >= eng.stats["step_count"] > 0
    text = drv.render_metrics()
    assert text.count("# TYPE serve_step_phase_seconds summary") == 1
    assert 'serve_step_phase_seconds{phase="dispatch",quantile="0.5"}' \
        in text
    assert 'serve_step_phase_seconds_sum{phase="dispatch"}' in text
    assert 'serve_step_phase_seconds_count{phase="dispatch"}' in text
    # driver-side health surface agrees with the engine
    h = drv.health()
    assert h["queue_depth"] == 0 and h["step_count"] > 0
    assert h["last_step_age_s"] is not None
    # flight + trace surfaces exist on the driver too
    assert drv.flight()["replicas"][0]["steps"]
    assert drv.trace()["traceEvents"]


def test_metrics_render_hammer_under_concurrent_observes():
    """Satellite: every metric class renders consistently while another
    thread observes — the single-lock snapshot must never produce a
    quantile/_sum/_count tear or crash."""
    reg = MetricsRegistry()
    h = reg.histogram("h_seconds", "plain", window=512)
    lh = reg.labeled_histogram("lh_seconds", "labeled", label="phase",
                               window=512)
    c = reg.counter("c_total")
    g = reg.gauge("g_now")
    stop = threading.Event()

    def pound():
        i = 0
        while not stop.is_set():
            h.observe(i % 7)
            lh.observe("a" if i % 2 else "b", i % 5)
            c.inc()
            g.set(i)
            i += 1

    threads = [threading.Thread(target=pound, daemon=True)
               for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for _ in range(300):
            text = reg.render()
            for line in text.splitlines():
                if line.startswith("#") or not line:
                    continue
                val = line.rsplit(" ", 1)[1]
                assert val == "NaN" or float(val) >= 0
        # snapshot consistency: sum/count/window from ONE lock hold
        for _ in range(200):
            window, total, count = h.snapshot()
            assert len(window) <= 512
            assert count >= len(window)
            assert all(window[i] <= window[i + 1]
                       for i in range(len(window) - 1))
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5.0)


# ------------------------------------------------------ HTTP observability

def test_healthz_and_debug_endpoints_respond_while_step_stalled():
    """Satellite: a wedged-but-alive engine still answers /healthz —
    lock-free — with a growing last_step_age_s and the real queue depth;
    /debug/flight and /debug/trace answer too."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(8), CFG, (6, 5, 7))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=16)
    eng.submit(100, prompts[0], max_new=2)
    eng.run()                           # warm traces

    calls = {"n": 0}
    stalled = threading.Event()

    def step_fn(drv):
        calls["n"] += 1
        if calls["n"] >= 2:             # wedge from the second step on
            stalled.set()
            while not drv.abort_step.is_set():
                time.sleep(0.005)
            return
        drv.engine.step()

    drv = AsyncDriver(eng, step_fn=step_fn, start=False)
    server = ServeHTTPServer(drv, port=0)
    try:
        for i, p in enumerate(prompts):
            drv.submit(p, max_new=8, rid=i)
        drv.start()
        assert stalled.wait(timeout=30.0)
        time.sleep(0.05)                # let the stall age a little

        def get(path):
            with urllib.request.urlopen(server.url + path,
                                        timeout=10) as r:
                return json.loads(r.read().decode())

        # the driver lock is HELD by the wedged step right now; these
        # must all answer anyway
        health = get("/healthz")
        assert health["status"] == "ok"
        assert health["step_in_flight_s"] > 0.0
        assert health["last_step_age_s"] > 0.0
        assert health["queue_depth"] >= 1      # slots=2, 3 requests
        assert health["step_count"] >= 1
        flight = get("/debug/flight")
        [rep] = flight["replicas"]
        assert rep["steps"], "flight ring must hold the warm steps"
        assert flight["snapshot"]["active"]
        trace = get("/debug/trace")
        assert trace["traceEvents"]
    finally:
        drv.abort_step.set()
        server.close(drain=False)


def test_scheduler_explain_lands_on_submitted_event():
    from repro.serve.scheduler import Priority
    params = _params(CFG)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      mixed=True, chunk_tokens=8, scheduler=Priority())
    p = np.arange(5, dtype=np.int32) % CFG.vocab_size
    eng.submit(0, p, max_new=2, priority=3)
    eng.run()
    tree = eng.tracer.request_trace(0)
    sub = next(e for e in tree["events"] if e["kind"] == "submitted")
    assert sub["policy"] == "priority" and sub["priority"] == 3
    assert sub["prompt_tokens"] == 5


def test_tracing_overhead_within_bounds():
    """Enabled-vs-disabled throughput on the bench smoke stays within
    5% — here we assert the cheap proxy: identical outputs and a wide
    sanity margin on wall time (CI's trace-smoke pins the real bench)."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(9), CFG, (16, 8, 12, 6))

    def run(level):
        eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                          mixed=True, chunk_tokens=16,
                          trace_level=level)
        for i, p in enumerate(prompts):
            eng.submit(i, p, max_new=6)
        t0 = time.perf_counter()
        res = eng.run()
        return time.perf_counter() - t0, \
            {r: list(v.out) for r, v in res.items()}

    run(1)                      # warm compile caches for both paths
    run(0)
    t_on, out_on = run(1)
    t_off, out_off = run(0)
    assert out_on == out_off
    # generous CI-safe envelope; the real 5% bound rides on the bench
    assert t_on < 3.0 * t_off + 0.25, (t_on, t_off)
