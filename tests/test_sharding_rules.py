"""Sharding-rule unit tests: param specs match shapes/divisibility; opt
state and cache specs derive correctly; ZeRO/FSDP add the data axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_smoke
from repro.core import sharding as shd
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.train.step import init_opt_state
from repro.launch.mesh import make_mesh


def _mesh():
    return make_mesh((2, 4), ("data", "model"))


def _check_divisible(pspecs, params, mesh):
    for spec, leaf in zip(jax.tree.leaves(pspecs,
                                          is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(params)):
        entries = tuple(spec) + (None,) * (leaf.ndim - len(tuple(spec)))
        for dim, ax in zip(leaf.shape, entries):
            if ax is None:
                continue
            size = np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))])
            assert dim % size == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_NAMES)
@pytest.mark.parametrize("fsdp", [False, True])
def test_param_specs_divisible(arch, fsdp):
    cfg = get_smoke(arch)
    mesh = _mesh()
    params = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.key(0), cfg))
    st = Strategy(fsdp=fsdp)
    pspecs = shd.param_pspecs(params, st, mesh)
    _check_divisible(pspecs, params, mesh)


def test_megatron_column_row_pattern():
    """wq/w_gate column-split, wo/w_down row-split — paper §5.1 exactly."""
    cfg = get_smoke("qwen3-14b")
    mesh = _mesh()
    params = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.key(0), cfg))
    specs = shd.param_pspecs(params, Strategy(), mesh)
    lp = specs["layers"]
    assert tuple(lp["attn"]["wq"]) == (None, None, "model")
    assert tuple(lp["attn"]["wo"]) == (None, "model", None)
    assert tuple(lp["mlp"]["w_gate"]) == (None, None, "model")
    assert tuple(lp["mlp"]["w_down"]) == (None, "model", None)
    assert tuple(specs["embed"]) == ("model", None)


def test_moe_expert_parallel_specs():
    cfg = get_smoke("olmoe-1b-7b")
    mesh = _mesh()
    params = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.key(0), cfg))
    specs = shd.param_pspecs(params, Strategy(expert_parallel=True), mesh)
    assert tuple(specs["layers"]["moe"]["w_gate"]) == (None, "model",
                                                       None, None)
    specs_tp = shd.param_pspecs(params, Strategy(expert_parallel=False),
                                mesh)
    assert tuple(specs_tp["layers"]["moe"]["w_gate"]) == (None, None,
                                                          None, "model")


def test_zero1_opt_state_adds_data_axis():
    cfg = get_smoke("minitron-4b")
    mesh = _mesh()
    params = get_model(cfg).init(jax.random.key(0), cfg)
    st = Strategy(zero1=True)
    opt = init_opt_state(params, st)
    ospecs = shd.opt_state_pspecs(opt, params, st, mesh)
    # AdamW m for w_gate: param spec (None,None,'model') + data on dim 1
    spec = tuple(ospecs["m"]["layers"]["mlp"]["w_gate"])
    assert "data" in spec and "model" in spec


def test_adafactor_state_specs_match_shapes():
    cfg = get_smoke("kimi-k2-1t-a32b")
    mesh = _mesh()
    params = get_model(cfg).init(jax.random.key(0), cfg)
    st = Strategy(optimizer="adafactor", zero1=True)
    opt = init_opt_state(params, st)
    ospecs = shd.opt_state_pspecs(opt, params, st, mesh)
    for leaf, spec in zip(jax.tree.leaves(opt["vr"]),
                          jax.tree.leaves(ospecs["vr"],
                                          is_leaf=lambda x:
                                          isinstance(x, P))):
        assert len(tuple(spec)) <= leaf.ndim + 1
    _check_divisible(ospecs["vr"], opt["vr"], mesh)
    _check_divisible(ospecs["vc"], opt["vc"], mesh)


def test_cache_specs_fallback_to_seq_sharding():
    """GQA kv_heads=2 can't shard over model=4 -> cache seq dim shards."""
    cfg = get_smoke("qwen3-14b")   # kv=2 in smoke
    mesh = _mesh()
    model = get_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(cfg, 8, 64))
    specs = shd.cache_pspecs(cache, Strategy(), mesh, batch=8)
    spec = tuple(specs["kv"]["k"])
    assert spec[2] == "model" and spec[3] is None  # seq sharded, heads not
