"""VLM serving (cross-attention image layers, stubbed ViT frontend):
``submit(..., images=)`` carries (num_image_tokens, d_model) patch
embeddings into engine prefill exactly as ``frames=`` carries encoder
input for enc-dec archs. VLM decode is not pageable (the cross-KV is a
separate per-slot buffer), so the engine serves it on the legacy
dense-layout split path — pinned here against a hand-rolled greedy
prefill + decode_step loop over the same model functions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.engine import ServeEngine

VLM_CFG = ModelConfig(name="serve-vlm", arch_type="vlm", num_layers=3,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, cross_attn_every=2,
                      num_image_tokens=8, dtype="float32")

DENSE_CFG = ModelConfig(name="serve-vlm-dense", arch_type="dense",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=2, d_ff=128, vocab_size=128,
                        dtype="float32")


def _params(cfg, seed=0):
    p = get_model(cfg).init(jax.random.key(seed), cfg)
    if cfg.arch_type == "vlm":
        # the tanh gates init to 0 (vision is a no-op at init, the
        # Llama-3.2 recipe) — open them so the image path actually
        # moves the logits under test
        p["cross"]["gate_attn"] = jnp.ones_like(p["cross"]["gate_attn"])
        p["cross"]["gate_mlp"] = jnp.ones_like(p["cross"]["gate_mlp"])
    return p


def _reference_greedy(cfg, params, prompt, images, new, max_len):
    """B=1 prefill + decode_step loop — the exactness oracle."""
    mod = get_model(cfg)
    cache = mod.init_cache(cfg, 1, max_len)
    batch = {"tokens": jnp.asarray(prompt, jnp.int32)[None],
             "image_embeds": jnp.asarray(images, jnp.float32)[None]}
    logits, cache = mod.prefill(params, batch, cfg, cache)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < new:
        logits, cache = mod.decode_step(
            params, cache, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray(pos, jnp.int32), cfg)
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out


def test_vlm_engine_matches_reference_greedy():
    params = _params(VLM_CFG)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, VLM_CFG.vocab_size,
                            size=(int(n),)).astype(np.int32)
               for n in (5, 9, 7)]
    images = [rng.standard_normal(
        (VLM_CFG.num_image_tokens, VLM_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    # slots=1: the engine's decode batch is (1, 1), the same shape the
    # reference loop runs, so the comparison is accumulation-exact
    eng = ServeEngine(VLM_CFG, params, slots=1, max_len=32)
    assert not eng.paged and not eng.mixed     # auto-resolved dense/split
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=6, images=images[i])
    res = eng.run()
    for i, p in enumerate(prompts):
        want = _reference_greedy(VLM_CFG, params, p, images[i], 6, 32)
        assert list(res[i].out) == want, i


def test_vlm_images_distinguish_requests():
    """Same prompt, different images: the cross-attention layers see the
    per-request embeddings (not a stale or shared buffer)."""
    params = _params(VLM_CFG)
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, VLM_CFG.vocab_size, size=(6,)).astype(np.int32)
    im_a = rng.standard_normal(
        (VLM_CFG.num_image_tokens, VLM_CFG.d_model)).astype(np.float32)
    im_b = rng.standard_normal(
        (VLM_CFG.num_image_tokens, VLM_CFG.d_model)).astype(np.float32)
    eng = ServeEngine(VLM_CFG, params, slots=2, max_len=32)
    eng.submit(0, prompt, max_new=8, images=im_a)
    eng.submit(1, prompt, max_new=8, images=im_b)
    eng.submit(2, prompt, max_new=8, images=im_a)
    res = eng.run()
    assert list(res[0].out) == list(res[2].out)
    assert list(res[0].out) != list(res[1].out)


def test_vlm_submit_validation():
    params = _params(VLM_CFG)
    eng = ServeEngine(VLM_CFG, params, slots=1, max_len=32)
    prompt = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="images"):
        eng.submit(0, prompt, max_new=2)       # vlm needs embeddings
    with pytest.raises(ValueError, match="shape"):
        eng.submit(0, prompt, max_new=2,
                   images=np.zeros((3, VLM_CFG.d_model), np.float32))
    dense = ServeEngine(DENSE_CFG, _params(DENSE_CFG), slots=1, max_len=32)
    with pytest.raises(ValueError, match="vlm"):
        dense.submit(0, prompt, max_new=2,
                     images=np.zeros((8, 64), np.float32))
