"""Optimizers, data pipeline, checkpointing, losses, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.data.pipeline import DataConfig, TokenDataset
from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update, clip_by_global_norm, cosine_lr)
from repro.train.losses import cross_entropy
from repro.launch.mesh import make_mesh


# ------------------------------------------------------------- optimizers

@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizer_decreases_quadratic(opt):
    w = {"a": jnp.array([[3.0, -2.0], [1.5, 4.0]]),
         "b": jnp.array([5.0, -5.0, 2.0])}
    init, update = ((adamw_init, adamw_update) if opt == "adamw"
                    else (adafactor_init, adafactor_update))
    state = init(w)

    def loss(w):
        return sum(jnp.sum(x**2) for x in jax.tree.leaves(w))

    l0 = float(loss(w))
    for _ in range(120):
        g = jax.grad(loss)(w)
        w, state = update(g, state, w, lr=5e-2, weight_decay=0.0)
    assert float(loss(w)) < 0.05 * l0


def test_clip_by_global_norm():
    g = {"x": jnp.ones((4,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_cosine_lr_schedule():
    assert float(cosine_lr(0, base_lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_lr(10, base_lr=1.0, warmup=10, total=100)) \
        == pytest.approx(1.0)
    assert float(cosine_lr(100, base_lr=1.0, warmup=10, total=100)) \
        == pytest.approx(0.1, rel=1e-3)


# ------------------------------------------------------------------ loss

def test_cross_entropy_uniform():
    v = 17
    logits = jnp.zeros((2, 3, v))
    labels = jnp.zeros((2, 3), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(np.log(v),
                                                                 rel=1e-5)


def test_cross_entropy_ignores_masked():
    logits = jax.random.normal(jax.random.key(0), (1, 4, 11))
    labels = jnp.array([[1, 2, -1, -1]], jnp.int32)
    full = cross_entropy(logits, labels)
    labels2 = jnp.array([[1, 2, 5, 7]], jnp.int32)
    assert float(full) != pytest.approx(float(cross_entropy(logits,
                                                            labels2)))


# ------------------------------------------------------------------ data

def test_data_determinism_and_shapes():
    cfg = DataConfig(vocab_size=512, seq_len=64, global_batch=8, seed=3)
    ds = TokenDataset(cfg)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    assert b1["labels"].shape == (8, 64)
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()
    b3 = ds.batch(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < 512


def test_data_has_learnable_motifs():
    cfg = DataConfig(vocab_size=512, seq_len=128, global_batch=4, seed=0)
    ds = TokenDataset(cfg)
    toks = ds.batch(0)["tokens"]
    # at least one arithmetic run of length >= 8 per row
    found = 0
    for row in toks:
        d = np.diff(row)
        run, best = 1, 1
        for i in range(1, len(d)):
            run = run + 1 if d[i] == d[i - 1] else 1
            best = max(best, run)
        found += best >= 8
    assert found >= 3


# ----------------------------------------------------------- checkpoints

def test_checkpoint_roundtrip(tmp_path):
    tree = {"layers": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                       "b": np.ones((4,), np.float32)},
            "step": np.asarray(7)}
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    zeros = jax.tree.map(np.zeros_like, tree)
    restored = load_checkpoint(tmp_path, 7, zeros)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": np.zeros((2, 2))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, 1, {"w": np.zeros((3, 3))})


# --------------------------------------------------------- HLO analyzer

def test_hlo_analyzer_scan_trip_counts():
    from repro.launch.hlo_analysis import analyze

    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    comp = jax.jit(f).lower(x, w).compile()
    s = analyze(comp.as_text())
    assert s.flops == pytest.approx(2 * 64**3 * 5)
    assert s.num_while >= 1


def test_hlo_analyzer_collectives():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import analyze
    mesh = make_mesh((1, 4), ("data", "model"))
    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "model")))
    ws = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                              sharding=NamedSharding(mesh, P("model", None)))
    comp = jax.jit(lambda a, b: a @ b,
                   out_shardings=NamedSharding(mesh, P(None, None))
                   ).lower(xs, ws).compile()
    s = analyze(comp.as_text())
    assert s.collectives.get("all-reduce", 0) == 64 * 64 * 4
    assert s.collective_bytes_dcn == 0
