"""Inter-operator (pipeline) parallelism: shard_map GPipe == sequential
reference; schedule simulator reproduces the paper's bubble formula."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pipeline import gpipe_spmd, pipeline_apply, simulate_schedule
from repro.launch.mesh import make_mesh, make_pipeline_mesh


def test_gpipe_matches_sequential():
    p_stages, m, mb, d = 4, 8, 2, 16
    mesh = make_mesh((1, 4, 1), ("data", "pipe", "model"))
    key = jax.random.key(0)
    w = jax.random.normal(key, (p_stages, d, d)) * 0.3
    x = jax.random.normal(jax.random.key(1), (m * mb, d))

    def stage_fn(wi, xx):
        return jnp.tanh(xx @ wi)

    out = pipeline_apply(lambda pw, xx: stage_fn(pw, xx), w, x,
                         mesh=mesh, num_microbatches=m)
    expect = x
    for i in range(p_stages):
        expect = stage_fn(w[i], expect)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


def test_gpipe_gradients_flow():
    """The pipeline must be differentiable (training viability)."""
    p_stages, m, mb, d = 2, 4, 2, 8
    mesh = make_mesh((1, 2, 1), ("data", "pipe", "model"))
    w = jax.random.normal(jax.random.key(0), (p_stages, d, d)) * 0.3
    x = jax.random.normal(jax.random.key(1), (m * mb, d))

    def loss(w):
        y = pipeline_apply(lambda pw, xx: jnp.tanh(xx @ pw), w, x,
                           mesh=mesh, num_microbatches=m)
        return (y ** 2).mean()

    g = jax.grad(loss)(w)

    def loss_seq(w):
        y = x
        for i in range(p_stages):
            y = jnp.tanh(y @ w[i])
        return (y ** 2).mean()

    g_ref = jax.grad(loss_seq)(w)
    np.testing.assert_allclose(g, g_ref, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("p,m", [(2, 4), (4, 8), (4, 32), (8, 64)])
def test_bubble_formula(p, m):
    """GPipe bubble == (p-1)/(m+p-1) — paper §4 / Fig. 5c/5d."""
    sim = simulate_schedule(p, m, schedule="gpipe", fwd_time=1.0,
                            bwd_time=2.0)
    assert sim["bubble_fraction"] == pytest.approx((p - 1) / (m + p - 1))


@pytest.mark.parametrize("p,m", [(4, 8), (8, 64)])
def test_1f1b_same_bubble_less_memory(p, m):
    g = simulate_schedule(p, m, schedule="gpipe")
    f = simulate_schedule(p, m, schedule="1f1b")
    assert f["bubble_fraction"] == pytest.approx(g["bubble_fraction"])
    assert (f["peak_inflight_microbatches"]
            <= g["peak_inflight_microbatches"])


def test_more_microbatches_shrink_bubble():
    """Fig. 5d: micro-batches fill the pipe faster."""
    bubbles = [simulate_schedule(4, m)["bubble_fraction"]
               for m in (1, 2, 4, 8, 16, 64)]
    assert all(b2 < b1 for b1, b2 in zip(bubbles, bubbles[1:]))
