"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode on CPU), plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------- flash attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,d", [(1, 128, 1, 64), (2, 256, 4, 64),
                                     (1, 512, 2, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 128)])
def test_flash_attention_sweep(b, s, h, d, dtype, causal, window):
    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol, rtol=tol)


def test_flash_attention_gqa_repeat():
    key = jax.random.key(1)
    q = jax.random.normal(key, (2, 128, 8, 64))
    k = jax.random.normal(key, (2, 128, 2, 64))
    v = jax.random.normal(key, (2, 128, 2, 64))
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    kr = jnp.repeat(k, 4, axis=2)
    vr = jnp.repeat(v, 4, axis=2)
    expect = ref.flash_attention_ref(q, kr, vr, causal=True)
    np.testing.assert_allclose(out, expect, atol=2e-6)


@settings(max_examples=10, deadline=None)
@given(s=hst.sampled_from([128, 256]),
       d=hst.sampled_from([64, 128]),
       seed=hst.integers(0, 2**30))
def test_flash_attention_property(s, d, seed):
    """Property: rows of the attention output are convex combinations of V
    rows => output is bounded by V's extrema."""
    key = jax.random.key(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, s, 1, d))
    k = jax.random.normal(ks[1], (1, s, 1, d))
    v = jax.random.normal(ks[2], (1, s, 1, d))
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    assert float(out.max()) <= float(v.max()) + 1e-4
    assert float(out.min()) >= float(v.min()) - 1e-4


# ------------------------------------------------------------ fused MLP

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,d,f", [(128, 64, 256), (256, 128, 512),
                                   (512, 256, 256)])
def test_fused_mlp_sweep(t, d, f, dtype):
    key = jax.random.key(2)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (t, d), dtype)
    wg = (jax.random.normal(ks[1], (d, f), dtype) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (d, f), dtype) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (f, d), dtype) * 0.05).astype(dtype)
    out = ops.fused_mlp(x, wg, wu, wd, block_m=128, block_f=128)
    expect = ref.fused_mlp_ref(x, wg, wu, wd)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol, rtol=tol)


def test_fused_mlp_matches_model_mlp():
    """The kernel must agree with the model-layer MLP it accelerates."""
    from repro.models.layers import mlp
    key = jax.random.key(3)
    ks = jax.random.split(key, 4)
    d, f = 64, 128
    x = jax.random.normal(ks[0], (2, 32, d))
    p = {"w_gate": jax.random.normal(ks[1], (d, f)) * 0.05,
         "w_up": jax.random.normal(ks[2], (d, f)) * 0.05,
         "w_down": jax.random.normal(ks[3], (f, d)) * 0.05}
    expect = mlp(p, x)
    out = ops.fused_mlp(x, p["w_gate"], p["w_up"], p["w_down"],
                        block_m=64, block_f=128)
    np.testing.assert_allclose(out, expect, atol=1e-5)


# ------------------------------------------------------------- SSD scan

@pytest.mark.parametrize("s,h,p,n,chunk", [(128, 2, 32, 16, 32),
                                           (256, 3, 64, 32, 64),
                                           (256, 1, 32, 64, 128)])
def test_ssd_scan_sweep(s, h, p, n, chunk):
    key = jax.random.key(4)
    ks = jax.random.split(key, 5)
    b = 2
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    out = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    expect = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-4)


def test_ssd_scan_matches_model_ssd():
    """Kernel vs the model's chunked XLA implementation."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.key(5)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 128, 2, 32, 16
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    y_kernel = ops.ssd_scan(x, dt, A, B, C, chunk=32)
    y_model, _ = ssd_chunked(x, dt, A, B, C, chunk=32)
    np.testing.assert_allclose(y_kernel, y_model, atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(seed=hst.integers(0, 2**30), chunk=hst.sampled_from([16, 32, 64]))
def test_ssd_chunk_invariance(seed, chunk):
    """Property: the chunked SSD result must be independent of chunk size."""
    key = jax.random.key(seed)
    ks = jax.random.split(key, 5)
    b, s, h, p, n = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n)) * 0.3
    C = jax.random.normal(ks[4], (b, s, n)) * 0.3
    out = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    expect = ref.ssd_scan_ref(x, dt, A, B, C)
    np.testing.assert_allclose(out, expect, atol=1e-4, rtol=1e-4)


def test_flash_attention_gradients():
    """The kernel's custom VJP must match autodiff through the oracle."""
    key = jax.random.key(7)
    ks = jax.random.split(key, 3)
    b, s, h, d = 1, 128, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))

    def loss_kernel(q, k, v):
        return (ops.flash_attention(q, k, v, causal=True, block_q=64,
                                    block_k=64) ** 2).mean()

    def loss_ref(q, k, v):
        return (ref.flash_attention_ref(q, k, v, causal=True) ** 2).mean()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, atol=2e-5, rtol=2e-4)
