"""Operator-graph IR (paper §3.1.2): analytical FLOPs/params vs the model
zoo's real counts; balanced pipeline-stage cuts."""
import jax
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke
from repro.core.opgraph import build_opgraph
from repro.models import get_model


@pytest.mark.parametrize("arch", ["qwen3-14b", "minitron-4b", "olmoe-1b-7b",
                                  "mamba2-780m"])
def test_param_count_matches_initializer(arch):
    """cfg.param_count() (used by MFU / roofline) must equal the real
    pytree size from the initializer, on the smoke config."""
    cfg = get_smoke(arch)
    params = jax.eval_shape(
        lambda: get_model(cfg).init(jax.random.key(0), cfg))
    real = sum(int(l.size) for l in jax.tree.leaves(params))
    pred = cfg.param_count()
    assert abs(real - pred) / real < 0.05, (real, pred)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_opgraph_builds_and_is_chained(arch):
    cfg = get_config(arch)
    g = build_opgraph(cfg, batch=4, seq=512)
    assert g.total_flops() > 0
    assert g.total_param_bytes() > 0
    names = {n.name for n in g.nodes}
    for a, b in g.edges:
        assert a in names and b in names
    assert len(g.edges) == len(g.nodes) - 1      # linear chain


def test_balanced_stages_cover_all_layers():
    cfg = get_config("deepseek-coder-33b")
    g = build_opgraph(cfg, 4, 512)
    for p in (2, 4, 8):
        stages = g.balanced_stages(p)
        assert len(stages) == p
        flat = [li for st in stages for li in st]
        assert sorted(flat) == sorted(set(flat))
        per = {k: sum(n.flops for n in v)
               for k, v in g.layer_nodes().items()}
        loads = [sum(per[li] for li in st) for st in stages if st]
        assert max(loads) / max(min(loads), 1) < 1.6   # balanced-ish


def test_flops_scale_linearly_with_tokens():
    cfg = get_config("internlm2-20b")
    f1 = build_opgraph(cfg, 2, 256).total_flops()
    f2 = build_opgraph(cfg, 4, 256).total_flops()
    assert f2 == pytest.approx(2 * f1, rel=1e-6)
