"""prefill + decode_step must reproduce teacher-forced forward logits
(fp32, exact to accumulation order) for every architecture family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_smoke
from repro.models import get_model

TOL = 5e-5


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_forward(arch):
    cfg = get_smoke(arch).with_(dtype="float32", moe_capacity_factor=16.0)
    mod = get_model(cfg)
    key = jax.random.key(0)
    params = mod.init(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.has_encoder:
        batch["frames"] = jax.random.normal(key,
                                            (B, cfg.encoder_ctx, cfg.d_model))
    if cfg.cross_attn_every > 0:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    full, _ = mod.forward(params, batch, cfg)
    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 4]
    cache = mod.init_cache(cfg, B, S)
    lg, cache = mod.prefill(params, pre, cfg, cache)
    assert float(jnp.abs(lg[:, 0] - full[:, S - 5]).max()) < TOL
    for i in range(4):
        pos = S - 4 + i
        lg, cache = mod.decode_step(params, cache, toks[:, pos:pos + 1],
                                    jnp.asarray(pos, jnp.int32), cfg)
        err = float(jnp.abs(lg[:, 0] - full[:, pos]).max())
        assert err < TOL, (pos, err)


def test_ring_cache_swa_decode():
    """Sliding-window arch with ring cache (window < seq) matches full
    forward with the same window."""
    cfg = get_smoke("qwen3-14b").with_(dtype="float32", sliding_window=16)
    mod = get_model(cfg)
    key = jax.random.key(3)
    params = mod.init(key, cfg)
    B, S = 2, 48
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, {"tokens": toks}, cfg)
    cache = mod.init_cache(cfg, B, S)          # ring: length 16
    assert cache["kv"]["k"].shape[2] == 16
    lg, cache = mod.prefill(params, {"tokens": toks[:, :32]}, cfg, cache)
    assert float(jnp.abs(lg[:, 0] - full[:, 31]).max()) < TOL
    for i in range(8):
        pos = 32 + i
        lg, cache = mod.decode_step(params, cache, toks[:, pos:pos + 1],
                                    jnp.asarray(pos, jnp.int32), cfg)
        err = float(jnp.abs(lg[:, 0] - full[:, pos]).max())
        assert err < TOL, (pos, err)


def test_ring_cache_unaligned_prefill():
    """Prompt length NOT a multiple of the window: fit_prefill must roll
    the kept rows so ring slot p%w really holds position p, or every
    post-prefill decode step attends to misaligned keys."""
    cfg = get_smoke("qwen3-14b").with_(dtype="float32", sliding_window=16)
    mod = get_model(cfg)
    key = jax.random.key(5)
    params = mod.init(key, cfg)
    B, S, P = 2, 48, 20                       # 16 < P < S, P % 16 != 0
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, {"tokens": toks}, cfg)
    cache = mod.init_cache(cfg, B, S)
    lg, cache = mod.prefill(params, {"tokens": toks[:, :P]}, cfg, cache)
    assert float(jnp.abs(lg[:, 0] - full[:, P - 1]).max()) < TOL
    for i in range(8):
        pos = P + i
        lg, cache = mod.decode_step(params, cache, toks[:, pos:pos + 1],
                                    jnp.asarray(pos, jnp.int32), cfg)
        err = float(jnp.abs(lg[:, 0] - full[:, pos]).max())
        assert err < TOL, (pos, err)
