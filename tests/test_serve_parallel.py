"""Plan-aware sharded serving (serve/parallel.py + ServeEngine mesh=):

tp-sharded engines and dp replica routing must serve greedy outputs
token-identical to the plain tp=1/dp=1 engine — on dense, MoE, enc-dec
and prefix-cache-on configs — while keeping exactly ONE decode trace per
replica and putting ~1/tp of the KV pool on each device. Router routing
policy (least-load + prefix affinity) is unit-tested host-side, no
device work. conftest forces 8 host devices, so tp2 x dp2 topologies fit.
"""
import jax
import numpy as np
import pytest

from repro.api import Degrees, Plan, Session
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.parallel import ReplicaRouter, replica_meshes

CFG = ModelConfig(name="par-dense", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

MOE_CFG = ModelConfig(name="par-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="par-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    # one prefill bucket (8): a single prefill trace per replica
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _serve(cfg, params, prompts, new, *, frames=None, mesh=None, slots=2,
           max_len=64, **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len, mesh=mesh,
                      **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new,
                   frames=None if frames is None else frames[i])
    results = eng.run()
    return {i: results[i].out for i in results}, eng


# -------------------------------------------------------------- tp parity

def test_tp2_dense_matches_tp1():
    """The head-sharded engine is token-identical to the unsharded one,
    still traces prefill/decode exactly once, and holds exactly half the
    pool per device."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(0), CFG, (5, 7, 6, 8, 5))
    base, be = _serve(CFG, params, prompts, 6, paged=True)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(CFG, params, prompts, 6, paged=True, mesh=mesh)
    assert tp2 == base
    assert te.tp == 2
    assert te.stats["decode_traces"] == 1
    # mixed stepping (paged default): prefill rides the decode program
    assert te.stats["prefill_traces"] == 0
    assert be.stats["decode_traces"] == 1
    # global pool bytes unchanged; per-device resident KV is 1/tp
    assert te.kv_bytes() == be.kv_bytes()
    assert te.per_device_kv_bytes() * 2 == be.per_device_kv_bytes()


def test_tp2_moe_matches_tp1():
    """Expert-parallel MoE decode under tp=2: single slot (the exactness
    regime the paged tests pin) stays token-identical to tp=1."""
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(6)
    prompts = _prompts(rng, MOE_CFG, (5, 8, 6))
    base, _ = _serve(MOE_CFG, params, prompts, 4, slots=1, max_len=32,
                     paged=True, page_size=8)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(MOE_CFG, params, prompts, 4, slots=1, max_len=32,
                     paged=True, page_size=8, mesh=mesh)
    assert tp2 == base
    assert te.stats["decode_traces"] == 1


def test_tp2_encdec_matches_tp1():
    """Enc-dec (audio): frames ride through the sharded prefill, the
    decoder KV pages shard by head, the cross-KV stays per-slot."""
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, AUDIO_CFG, (4, 7, 5))
    frames = [rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    base, _ = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                     max_len=32, paged=True)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                     max_len=32, paged=True, mesh=mesh)
    assert tp2 == base
    assert te.stats["decode_traces"] == 1

    router = ReplicaRouter(AUDIO_CFG, params, dp=2, slots=2, max_len=32,
                           paged=True)
    for i, (p, f) in enumerate(zip(prompts, frames)):
        router.submit(i, p, max_new=5, frames=f)
    res = router.run()
    assert {i: res[i].out for i in res} == base
    assert all(r["decode_traces"] == 1
               for r in router.stats["replicas"])


def test_tp2_prefix_cache_lazy_matches_tp1():
    """Sharing + lazy growth under tp: host-side page bookkeeping is
    layout-blind, so CoW/adoption still only rewrites table values — one
    decode trace, same tokens, real prefix hits."""
    params = _params(CFG)
    rng = np.random.default_rng(4)
    sys_p = rng.integers(0, CFG.vocab_size, size=(16,))
    prompts = [np.concatenate(
        [sys_p, rng.integers(0, CFG.vocab_size, size=(5,))]
    ).astype(np.int32) for _ in range(4)]
    kw = dict(paged=True, prefix_cache=True, lazy=True)
    base, be = _serve(CFG, params, prompts, 6, **kw)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(CFG, params, prompts, 6, mesh=mesh, **kw)
    assert tp2 == base
    assert te.stats["decode_traces"] == 1
    assert te.stats["prefix_hit_blocks"] > 0
    assert te.stats["prefix_hit_blocks"] == be.stats["prefix_hit_blocks"]


# -------------------------------------------------------------- dp parity

def test_dp2_router_matches_single_engine():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(1), CFG, (5, 7, 6, 8, 5, 7))
    base, _ = _serve(CFG, params, prompts, 6, paged=True)
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True)
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=6)
    res = router.run()
    assert {i: res[i].out for i in res} == base
    assert all(res[i].done for i in res)
    st = router.stats
    assert [r["decode_traces"] for r in st["replicas"]] == [1, 1]
    # both replicas actually served traffic
    assert all(r["prefills"] > 0 for r in st["replicas"])


def test_dp2_tp2_full_topology_matches():
    """The full dp2 x tp2 = 4-device topology: sharded replicas behind
    the router still produce the single-engine tokens, one decode trace
    per replica, per-device KV at 1/tp."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(2), CFG, (5, 7, 6, 8))
    base, be = _serve(CFG, params, prompts, 6, paged=True)
    router = ReplicaRouter(CFG, params, dp=2, tp=2, slots=2, max_len=64,
                           paged=True)
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=6)
    res = router.run()
    assert {i: res[i].out for i in res} == base
    assert [r["decode_traces"] for r in router.stats["replicas"]] == [1, 1]
    assert router.per_device_kv_bytes() * 2 == be.per_device_kv_bytes()
    # replica device slices are disjoint
    devs = [set(d.id for d in np.asarray(m.devices).ravel())
            for m in router.meshes]
    assert not (devs[0] & devs[1]) and all(len(d) == 2 for d in devs)


# --------------------------------------------------------- routing policy

def test_router_least_load_spreads():
    """No prefix cache: submissions alternate across replicas (pure
    least-load, lowest index breaking ties); nothing touches the
    device."""
    params = _params(CFG)
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True)
    rng = np.random.default_rng(0)
    homes = [router.submit(i, rng.integers(0, 128, size=(6,)), max_new=4)
             for i in range(6)]
    assert homes == [0, 1, 0, 1, 0, 1]
    assert router.replica_of(3) == 1
    with pytest.raises(ValueError, match="already submitted"):
        router.submit(3, rng.integers(0, 128, size=(6,)), max_new=4)


def test_router_prefix_affinity():
    """Same-first-block requests follow the replica holding the shared
    pages even when it is (boundedly) more loaded; an overloaded
    affinity target falls back to least-load."""
    params = _params(CFG)
    router = ReplicaRouter(CFG, params, dp=2, slots=1, max_len=64,
                           paged=True, prefix_cache=True)
    rng = np.random.default_rng(1)
    shared = rng.integers(0, 128, size=(16,))
    mk = lambda: np.concatenate(
        [shared, rng.integers(0, 128, size=(4,))]).astype(np.int32)
    assert router.submit(0, mk(), max_new=4) == 0      # least-load
    assert router.submit(1, rng.integers(0, 128, size=(6,)),
                         max_new=4) == 1               # least-load
    # replica 0 is now as loaded as 1, but holds the shared prefix
    assert router.submit(2, mk(), max_new=4) == 0      # affinity
    assert router.submit(3, mk(), max_new=4) == 0      # still affinity
    # affinity gives up once replica 0 is > slots behind the minimum
    assert router.route(mk()) == 1
    # short prompts (no full page-aligned block) never key affinity
    assert router._affinity_key(np.arange(3)) is None


def test_replica_meshes_validation():
    with pytest.raises(ValueError, match="devices needed"):
        replica_meshes(4, 4)
    with pytest.raises(ValueError, match="must be >= 1"):
        replica_meshes(0, 2)
    meshes = replica_meshes(2, 2)
    assert len(meshes) == 2
    assert all(m.shape["model"] == 2 and m.shape["data"] == 1
               for m in meshes)


# ----------------------------------------------------------- Session wiring

def test_session_serve_plan_defaults():
    """Session.from_plan(...).serve() picks the plan's tp/dp; explicit
    tp=/dp= override; a plain Session stays a single unsharded engine;
    pp>1 plans are rejected with guidance."""
    shape = ShapeConfig("host", 64, 8, "train")
    p = Plan.from_degrees(CFG, shape, Degrees(dp=2, tp=2, pp=1))
    session = Session.from_plan(CFG, p, devices=4, dtype="float32",
                                remat=False)
    eng = session.serve(slots=2, max_len=64)
    assert isinstance(eng, ReplicaRouter)
    assert (eng.dp, eng.tp) == (2, 2)
    # the router serves on the devices the plan materialized
    plan_devs = set(d.id for d in np.asarray(session.mesh.devices).ravel())
    mesh_devs = set(d.id for m in eng.meshes
                    for d in np.asarray(m.devices).ravel())
    assert mesh_devs == plan_devs

    single = session.serve(tp=1, dp=1, slots=2, max_len=64)
    assert isinstance(single, ServeEngine) and single.mesh is None

    tp_only = session.serve(tp=2, dp=1, slots=2, max_len=64)
    assert isinstance(tp_only, ServeEngine) and tp_only.tp == 2

    plain = Session(CFG, Strategy(dtype="float32")).serve(slots=2,
                                                          max_len=64)
    assert isinstance(plain, ServeEngine) and plain.mesh is None

    pp_plan = Plan.from_degrees(CFG, shape, Degrees(dp=1, tp=2, pp=2))
    pp_sess = Session.from_plan(CFG, pp_plan, devices=4, dtype="float32",
                                remat=False)
    with pytest.raises(ValueError, match="pp"):
        pp_sess.serve(slots=2, max_len=64)
    # explicit overrides bypass the pp plan entirely
    assert isinstance(pp_sess.serve(tp=1, dp=1, slots=2, max_len=64),
                      ServeEngine)


def test_session_serve_tp2_matches_plain():
    """End to end through the facade: Session.serve(tp=2) produces the
    same tokens as the plain engine on the same params."""
    session = Session(CFG, Strategy(dtype="float32", remat=False))
    rng = np.random.default_rng(3)
    prompts = _prompts(rng, CFG, (5, 7, 6))
    plain = session.serve(slots=2, max_len=64)
    sharded = session.serve(tp=2, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        plain.submit(i, p, max_new=6)
        sharded.submit(i, p, max_new=6)
    a, b = plain.run(), sharded.run()
    assert {i: a[i].out for i in a} == {i: b[i].out for i in b}
    assert sharded.stats["decode_traces"] == 1
