"""Paged KV-cache serving: the block-table engine is token-identical to
the dense engine (and to sequential greedy decoding) on every
full-attention arch, keeps the one-decode-trace property, packs short
requests where dense rows strand memory, and falls back to dense for
ring/SSM archs. Plus a seeded (hypothesis-free) churn check of the page
allocator's invariants — the @given variant is tests/test_paged_allocator.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.paging import PageAllocator, pages_for
from repro.serve.step import greedy_generate

CFG = ModelConfig(name="paged-dense", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

MOE_CFG = ModelConfig(name="paged-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="paged-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _serve(cfg, params, prompts, new, *, frames=None, slots=2, max_len=64,
           **kw):
    eng = ServeEngine(cfg, params, slots=slots, max_len=max_len, **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new,
                   frames=None if frames is None else frames[i])
    results = eng.run()
    return {i: results[i].out for i in results}, eng


# ------------------------------------------------------------------ parity

def test_paged_matches_dense_and_sequential_transformer():
    """Dense arch: paged vs dense engines vs per-request greedy decode are
    token-identical across staggered admissions, one decode trace each."""
    params = _params(CFG)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 7, 6, 11)]
    seq = {}
    for i, p in enumerate(prompts):
        toks = greedy_generate(params, CFG, Strategy(),
                               {"tokens": jnp.asarray(p)[None]}, steps=6)
        seq[i] = [int(t) for t in toks[0]]
    dense, de = _serve(CFG, params, prompts, 6, paged=False)
    paged, pe = _serve(CFG, params, prompts, 6, paged=True, page_size=16)
    assert not de.paged and pe.paged
    assert dense == seq
    assert paged == seq
    assert de.stats["decode_traces"] == 1
    assert pe.stats["decode_traces"] == 1


def test_paged_matches_dense_moe():
    """MoE: with ONE slot every decode batch is a single always-active row,
    so capacity routing sees identical inputs under both layouts and
    outputs match exactly. (With >1 slot, parity is NOT structurally
    guaranteed: an INACTIVE row attends stale per-slot KV under the dense
    layout but null-page scratch under the paged one, and capacity-based
    routing couples its garbage token to the active rows' expert budget —
    so the multi-slot check only asserts serving completeness.)"""
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, MOE_CFG.vocab_size,
                            size=(int(rng.integers(3, 10)),)).astype(np.int32)
               for _ in range(5)]
    dense, _ = _serve(MOE_CFG, params, prompts, 4, slots=1, max_len=32,
                      paged=False)
    paged, pe = _serve(MOE_CFG, params, prompts, 4, slots=1, max_len=32,
                       paged=True, page_size=8)
    assert dense == paged
    assert pe.stats["decode_traces"] == 1

    batched, be = _serve(MOE_CFG, params, prompts, 4, slots=3, max_len=32,
                         paged=True, page_size=8)
    assert set(batched) == set(range(5))
    assert all(0 <= t < MOE_CFG.vocab_size
               for out in batched.values() for t in out)
    assert be.stats["decode_traces"] == 1


def test_paged_matches_dense_and_sequential_encdec():
    """Enc-dec (audio) serving: per-request frame embeddings ride through
    prefill, the decoder KV pages, the cross-KV stays per-slot — outputs
    match sequential greedy decode exactly on both layouts."""
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, AUDIO_CFG.vocab_size,
                            size=(n,)).astype(np.int32) for n in (4, 7, 5)]
    frames = [rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    seq = {}
    for i, (p, f) in enumerate(zip(prompts, frames)):
        toks = greedy_generate(
            params, AUDIO_CFG, Strategy(),
            {"tokens": jnp.asarray(p)[None], "frames": jnp.asarray(f)[None]},
            steps=5)
        seq[i] = [int(t) for t in toks[0]]
    dense, de = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                       max_len=32, paged=False)
    paged, pe = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                       max_len=32, paged=True, page_size=8)
    assert dense == seq
    assert paged == seq
    assert de.stats["decode_traces"] == 1
    assert pe.stats["decode_traces"] == 1


# ------------------------------------------------------------ fragmentation

def test_paged_fragmentation_8_short_prompts_where_dense_fits_2():
    """Equal token budget (2 * max_len = 128 cache tokens): the dense
    layout spends it on 2 whole rows -> 2 concurrent requests; the paged
    pool spends it on 16-token pages -> all 8 short requests resident at
    once, outputs still identical."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, CFG.vocab_size, size=(10,)).astype(np.int32)
               for _ in range(8)]
    dense = ServeEngine(CFG, params, slots=2, max_len=64, paged=False)
    paged = ServeEngine(CFG, params, slots=8, max_len=64, paged=True,
                        page_size=16, kv_pages=8)     # 8*16 == 2*64 tokens
    for i, p in enumerate(prompts):
        dense.submit(i, p, max_new=6)
        paged.submit(i, p, max_new=6)        # ctx_cap 15 -> 1 page each
    dense.step()
    paged.step()
    assert sum(r is not None for r in dense.active) == 2
    assert sum(r is not None for r in paged.active) == 8
    rd, rp = dense.run(), paged.run()
    assert all(rd[i].done and rp[i].done for i in range(8))
    assert all(rd[i].out == rp[i].out for i in range(8))
    assert paged.stats["decode_traces"] == 1
    # same token budget on the pool side (+1 page: the null/scratch page)
    assert paged.kv_pages * paged.page_size == 2 * 64
    per_token_dense = dense.kv_bytes() / (2 * 64)
    assert paged.kv_bytes() == pytest.approx(
        per_token_dense * (paged.kv_pages + 1) * paged.page_size)


def test_paged_pool_releases_pages_and_backpressures():
    """A pool smaller than the workload serializes admission (head-of-line
    waits for retirements) but never deadlocks, never double-books pages,
    and drains back to an empty pool."""
    params = _params(CFG, seed=1)
    rng = np.random.default_rng(3)
    eng = ServeEngine(CFG, params, slots=4, max_len=64, paged=True,
                      page_size=16, kv_pages=3)       # room for ~1.5 reqs
    for i in range(6):
        eng.submit(i, rng.integers(0, CFG.vocab_size,
                                   size=(int(rng.integers(3, 12)),)),
                   max_new=5)                         # ctx_cap <= 16+
    results = eng.run()
    assert all(results[i].done for i in range(6))
    assert eng._alloc.pages_in_use == 0
    assert eng._alloc.free_pages == eng.kv_pages
    assert (eng._ptab == 0).all()
    assert eng.stats["decode_traces"] == 1


# ----------------------------------------------------- layout selection/API

def test_paged_auto_fallback_swa_and_ssm():
    swa_cfg = CFG.with_(name="paged-swa", sliding_window=8)
    eng = ServeEngine(swa_cfg, _params(swa_cfg, seed=3), slots=2, max_len=32)
    assert not eng.paged                       # ring cache keeps dense rows
    ssm_cfg = ModelConfig(name="paged-ssm", arch_type="ssm", num_layers=2,
                          d_model=64, num_heads=0, num_kv_heads=0, d_ff=128,
                          ssm_state=16, ssm_heads=4, ssm_head_dim=16,
                          vocab_size=128, dtype="float32")
    eng = ServeEngine(ssm_cfg, _params(ssm_cfg, seed=4), slots=2, max_len=32)
    assert not eng.paged
    with pytest.raises(ValueError, match="paged KV"):
        ServeEngine(swa_cfg, _params(swa_cfg, seed=3), slots=2, max_len=32,
                    paged=True)


def test_submit_rejects_pool_overflow_with_page_message():
    """A request whose worst-case context can NEVER fit the pool is
    rejected at submit with a page-denominated message (not 'cache row')."""
    params = _params(CFG, seed=1)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=16, kv_pages=2)
    with pytest.raises(ValueError, match="KV pages"):
        eng.submit(0, np.arange(30, dtype=np.int32), max_new=30)
    eng.submit(1, np.arange(20, dtype=np.int32), max_new=10)  # 2 pages: ok
    assert len(eng.queue) == 1


def test_audio_frames_validation():
    params = _params(AUDIO_CFG, seed=2)
    eng = ServeEngine(AUDIO_CFG, params, slots=1, max_len=32)
    with pytest.raises(ValueError, match="frames"):
        eng.submit(0, np.arange(4, dtype=np.int32), max_new=2)
    with pytest.raises(ValueError, match="shape"):
        eng.submit(0, np.arange(4, dtype=np.int32), max_new=2,
                   frames=np.zeros((3, 3), np.float32))
    dense_eng = ServeEngine(CFG, _params(CFG), slots=1, max_len=32)
    with pytest.raises(ValueError, match="audio"):
        dense_eng.submit(0, np.arange(4, dtype=np.int32), max_new=2,
                         frames=np.zeros((12, 64), np.float32))


# ------------------------------------------- allocator churn (no hypothesis)

def test_allocator_extend_unknown_owner_raises_keyerror():
    """Regression: extend() on an owner that holds no pages is a LOOKUP
    failure — KeyError, never a silently minted owner entry."""
    alloc = PageAllocator(4, 2, first_page=1)
    with pytest.raises(KeyError):
        alloc.extend("ghost", 4)
    assert "ghost" not in alloc.owners()
    assert alloc.free_pages == 4
    alloc.alloc("ghost", 2)
    assert alloc.extend("ghost", 4) is not None      # now it exists


def test_allocator_refcount_sharing_seeded_churn():
    """Seeded random churn over the SHARING ops (adopt-on-alloc, raw
    ref/deref, copy-on-write) — the hypothesis-free twin of
    test_paged_allocator.py's refcounted suite. Invariants: refcount
    conservation (pages_in_use == unique pages across owners + cache,
    each refcount == owner listings + raw refs), no double-free, and
    writer isolation after CoW."""
    rng = np.random.default_rng(7)
    for trial in range(15):
        num_pages = int(rng.integers(1, 12))
        page_size = int(rng.integers(1, 5))
        alloc = PageAllocator(num_pages, page_size, first_page=1)
        owners = {}                     # owner -> expected page list
        cache = {}                      # page -> raw ref count

        def live():
            pages = {p for ps in owners.values() for p in ps}
            return pages | {p for p, c in cache.items() if c > 0}

        def rc(page):
            return (sum(ps.count(page) for ps in owners.values())
                    + cache.get(page, 0))

        for _ in range(120):
            op = rng.choice(["alloc", "extend", "free", "ref", "deref",
                             "cow"])
            o = int(rng.integers(0, 4))
            if op == "alloc" and o not in owners:
                n = int(rng.integers(0, 25))
                donor = owners.get(int(rng.integers(0, 4)), [])
                want = pages_for(n, page_size)
                shared = donor[:min(int(rng.integers(0, 5)), want)]
                got = alloc.alloc(o, n, shared=shared)
                fits = want - len(shared) <= num_pages - len(live())
                assert (got is not None) == fits
                if got is not None:
                    assert got[:len(shared)] == shared
                    owners[o] = list(got)
            elif op == "extend" and o in owners:
                new_len = (len(owners[o]) * page_size
                           + int(rng.integers(0, 10)))
                extra = pages_for(new_len, page_size) - len(owners[o])
                got = alloc.extend(o, new_len)
                assert (got is not None) == \
                    (extra <= num_pages - len(live()))
                if got is not None:
                    owners[o].extend(got)
            elif op == "free" and o in owners:
                assert alloc.free(o) == owners.pop(o)
            elif op == "ref" and owners.get(o):
                p = owners[o][int(rng.integers(0, len(owners[o])))]
                alloc.ref(p)
                cache[p] = cache.get(p, 0) + 1
            elif op == "deref":
                pinned = sorted(p for p, c in cache.items() if c > 0)
                if pinned:
                    p = pinned[int(rng.integers(0, len(pinned)))]
                    alloc.deref(p)
                    cache[p] -= 1
            elif op == "cow" and owners.get(o):
                blk = int(rng.integers(0, len(owners[o])))
                old = owners[o][blk]
                was_shared = rc(old) > 1
                got = alloc.cow(o, blk)
                if not was_shared:
                    assert got == old
                elif num_pages - len(live()) > 0:
                    assert got is not None and got not in live()
                    owners[o][blk] = got
                    # writer isolation: fresh private page; the original
                    # keeps every other holder
                    assert alloc.refcount(got) == 1
                    assert alloc.refcount(old) == rc(old)
                else:
                    assert got is None
            # invariants: unique-live conservation + per-page refcounts
            assert alloc.pages_in_use == len(live())
            assert alloc.free_pages == num_pages - len(live())
            assert alloc.refcounts() == {p: rc(p) for p in live()}
            for own, pages in owners.items():
                assert alloc.pages_of(own) == pages


def test_allocator_seeded_churn_invariants():
    """Seeded random alloc/extend/free churn (the hypothesis-free twin of
    test_paged_allocator.py): ownership is exclusive, frees are complete,
    pages-in-use tracks sum(ceil(len/page_size)) exactly."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        num_pages = int(rng.integers(1, 14))
        page_size = int(rng.integers(1, 9))
        alloc = PageAllocator(num_pages, page_size, first_page=1)
        lens = {}
        for _ in range(150):
            op = rng.choice(["alloc", "extend", "free"])
            owner = int(rng.integers(0, 5))
            n = int(rng.integers(0, 40))
            if op == "alloc" and owner not in lens:
                got = alloc.alloc(owner, n)
                fits = (sum(pages_for(v, page_size) for v in lens.values())
                        + pages_for(n, page_size)) <= num_pages
                assert (got is not None) == fits
                if got is not None:
                    lens[owner] = n
            elif op == "extend" and owner in lens:
                new_len = lens[owner] + n
                extra = (pages_for(new_len, page_size)
                         - pages_for(lens[owner], page_size))
                got = alloc.extend(owner, new_len)
                fits = extra <= alloc.num_pages - sum(
                    pages_for(v, page_size) for v in lens.values())
                assert (got is not None) == fits
                if got is not None:
                    lens[owner] = new_len
            elif op == "free" and owner in lens:
                freed = alloc.free(owner)
                assert len(freed) == pages_for(lens.pop(owner), page_size)
            # invariants
            owned = [p for o in list(alloc.owners())
                     for p in alloc.pages_of(o)]
            assert len(owned) == len(set(owned))
            assert alloc.free_pages + len(owned) == num_pages
            assert alloc.pages_in_use == sum(
                pages_for(v, page_size) for v in lens.values())


# ------------------------------------------- admission rejection (deadlock)

def test_submit_rejects_requests_the_pool_can_never_hold():
    """A request whose minimum admission reservation exceeds the TOTAL
    pool could never be placed — without the submit()-time ValueError it
    would queue forever at the scheduler's head and wedge everything
    behind it (head-of-line admission). Eager reserves the worst case up
    front; lazy reserves the prompt + its first decode write, but ALSO
    bounds the worst case (preemption liveness: a lone survivor's extend
    must eventually fit the pool)."""
    params = _params(CFG)
    prompt = np.arange(20, dtype=np.int32) % CFG.vocab_size

    eager = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                        page_size=16, kv_pages=2)
    with pytest.raises(ValueError, match="worst-case"):
        eager.submit(0, prompt, max_new=40)       # 4 pages > pool of 2
    assert not eager.queue

    lazy = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                       page_size=16, kv_pages=1, lazy=True)
    with pytest.raises(ValueError, match="minimum admission reservation"):
        lazy.submit(0, prompt, max_new=2)         # prompt+1 -> 2 pages > 1
    # min fits (2 pages) but the worst case (4 pages) never could: the
    # request would be admitted, outgrow the pool mid-decode, and requeue
    # forever — the liveness bound rejects it up front
    lazy2 = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                        page_size=16, kv_pages=2, lazy=True)
    with pytest.raises(ValueError, match="worst-case"):
        lazy2.submit(0, np.arange(10, dtype=np.int32), max_new=60)

    # boundary: exactly-at-pool requests are admitted and drain
    ok = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                     page_size=16, kv_pages=2)
    ok.submit(0, prompt, max_new=6)               # worst 25 tok -> 2 pages
    results = ok.run()
    assert results[0].done and len(results[0].out) == 6


# ------------------------------------------------ bounded-gather high-water

def test_paged_gather_bounded_by_live_high_water():
    """The decode program's page table is clipped to the power-of-two
    bucket of the live page high-water mark: short requests gather 2 of
    the 8 table blocks (cost tracks occupancy, not max_len), outputs
    stay exact, and the trace count moves ONLY when a longer admission
    crosses a bucket boundary."""
    params = _params(CFG)
    rng = np.random.default_rng(7)
    short = [rng.integers(0, CFG.vocab_size, size=(5,)).astype(np.int32)
             for _ in range(3)]
    long_p = rng.integers(0, CFG.vocab_size, size=(20,)).astype(np.int32)
    expected = {}
    for i, p in enumerate(short + [long_p]):
        toks = greedy_generate(params, CFG, Strategy(),
                               {"tokens": jnp.asarray(p)[None]}, steps=6)
        expected[i] = [int(t) for t in toks[0]]

    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=8)                # table width: 8 blocks
    for i, p in enumerate(short):
        eng.submit(i, p, max_new=6)               # worst 10 tok -> 2 pages
    res1 = eng.run()
    assert {i: res1[i].out for i in range(3)} == \
        {i: expected[i] for i in range(3)}
    assert eng._gather == 2                       # bucket(2) of 8 blocks
    assert eng._cache["ptab"].shape[1] == 2
    assert eng.stats["decode_traces"] == 1

    # same-bucket traffic re-uses the program...
    eng.submit(10, short[0], max_new=6)
    eng.run()
    assert eng.stats["decode_traces"] == 1

    # ...a longer request re-buckets exactly once (2 -> bucket(4) = 4)
    eng.submit(3, long_p, max_new=6)              # worst 25 tok -> 4 pages
    res2 = eng.run()
    assert res2[3].out == expected[3]
    assert eng._gather == 4
    assert eng._cache["ptab"].shape[1] == 4
    assert eng.stats["decode_traces"] == 2
