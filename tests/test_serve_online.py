"""Online serving front-end (serve/metrics.py, driver.py, server.py):

quantile/histogram math is pinned on edge cases (empty -> NaN, one
sample -> that sample), the AsyncDriver's streamed greedy output must be
BIT-IDENTICAL to a batch ``run()`` over the same submissions (dense, tp2
and dp2 backends), the watchdog must detect an injected stalled step —
diagnostic dump at ERROR, cancel-and-requeue recovery, request still
completes with parity — and the HTTP layer is exercised over a real
socket (/generate JSON + chunked streaming, /metrics Prometheus text,
/healthz). conftest forces 8 host devices so the sharded backends fit.
"""
import json
import logging
import math
import re
import time
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.driver import AsyncDriver
from repro.serve.engine import ServeEngine
from repro.serve.metrics import (Histogram, MetricsRegistry, ServeMetrics,
                                 quantile)
from repro.serve.parallel import ReplicaRouter, replica_meshes
from repro.serve.server import ServeHTTPServer, serve_http

CFG = ModelConfig(name="online-dense", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
            for n in lens]


def _batch_reference(cfg, params, prompts, new, **kw):
    """Greedy outputs from a plain batch run() — the parity target."""
    eng = ServeEngine(cfg, params, **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new)
    results = eng.run()
    return {i: results[i].out for i in results}


# ------------------------------------------------------- metric math

def test_quantile_empty_is_nan():
    assert math.isnan(quantile([], 0.5))
    h = Histogram("h")
    assert all(math.isnan(v) for v in h.quantiles())
    assert 'h{quantile="0.5"} NaN' in "\n".join(h.render())


def test_quantile_one_sample_is_that_sample():
    assert quantile([7.0], 0.0) == 7.0
    assert quantile([7.0], 0.5) == 7.0
    assert quantile([7.0], 1.0) == 7.0
    h = Histogram("h")
    h.observe(0.25)
    assert h.quantiles([0.5, 0.9, 0.99]) == [0.25, 0.25, 0.25]


def test_quantile_linear_interpolation():
    vals = [float(v) for v in range(101)]       # 0..100 ascending
    assert quantile(vals, 0.5) == 50.0
    assert quantile(vals, 0.9) == 90.0
    assert quantile(vals, 0.99) == 99.0
    assert quantile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.5
    with pytest.raises(ValueError):
        quantile(vals, 1.5)


def test_histogram_window_exact_count_sum():
    h = Histogram("h", window=4)
    for v in range(1, 11):                      # 1..10
        h.observe(float(v))
    assert h.count == 10                        # count/sum are exact...
    assert h.sum == 55.0
    # ...quantiles window to the most recent 4 samples (7,8,9,10)
    assert h.quantile(0.0) == 7.0
    assert h.quantile(1.0) == 10.0


def test_registry_render_and_reset():
    r = MetricsRegistry()
    c = r.counter("reqs_total", "requests")
    g = r.gauge("depth")
    h = r.histogram("lat_seconds")
    c.inc(3)
    g.set(2)
    h.observe(0.5)
    h.observe(1.5)
    text = r.render()
    assert "# HELP reqs_total requests" in text
    assert "# TYPE reqs_total counter" in text
    assert "reqs_total 3.0" in text
    assert "# TYPE lat_seconds summary" in text
    assert 'lat_seconds{quantile="0.5"} 1.0' in text
    assert "lat_seconds_sum 2.0" in text
    assert "lat_seconds_count 2" in text
    with pytest.raises(ValueError):
        c.inc(-1)                               # counters only go up
    with pytest.raises(ValueError):
        r.counter("reqs_total")                 # duplicate name
    r.reset()
    assert c.value == 0.0 and g.value == 0.0 and h.count == 0


def test_serve_metrics_render_engine_stats():
    m = ServeMetrics()
    m.ttft.observe(0.1)
    text = m.render(extra={"pages_in_use": 3, "paged": True,
                           "replicas": [{"x": 1}], "wall_time_s": 0.5})
    assert 'serve_ttft_seconds{quantile="0.5"} 0.1' in text
    assert "serve_engine_pages_in_use 3.0" in text
    assert "serve_engine_wall_time_s 0.5" in text
    # bools and the router's per-replica list are not gauges
    assert "serve_engine_paged" not in text
    assert "serve_engine_replicas" not in text
    lat = m.latency_summary()
    assert lat["ttft_p50_s"] == 0.1
    assert math.isnan(lat["tpot_p99_s"])        # nothing observed yet


# --------------------------------------------------- streaming parity

def _driver_outputs(eng, prompts, new, *, deferred=True, **drv_kw):
    """Serve ``prompts`` through an AsyncDriver; returns ({rid: out},
    driver). Deferred start admits exactly like batch run()."""
    drv = AsyncDriver(eng, start=not deferred, **drv_kw)
    streams = [drv.submit(p, max_new=new, rid=i)
               for i, p in enumerate(prompts)]
    if deferred:
        drv.start()
    out = {s.rid: s.tokens() for s in streams}
    records = {s.rid: s.result(timeout=60.0) for s in streams}
    drv.stop(drain=True)
    assert all(r.done for r in records.values())
    # the stream yielded exactly the record's tokens, in order
    assert out == {rid: list(r.out) for rid, r in records.items()}
    return out, drv


def test_stream_matches_run_dense():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(0), CFG, (5, 7, 6, 8))
    base = _batch_reference(CFG, params, prompts, 6, slots=2, max_len=64,
                            paged=True)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)
    out, drv = _driver_outputs(eng, prompts, 6)
    assert out == base
    assert eng.stats["decode_traces"] == 1      # driver loop retraced nothing
    # per-request latencies landed: one TTFT per request, finite p50s
    assert drv.metrics.ttft.count == len(prompts)
    assert drv.metrics.completed.value == len(prompts)
    lat = drv.metrics.latency_summary()
    assert lat["ttft_p50_s"] > 0.0
    assert lat["tpot_p50_s"] >= 0.0
    # driver bookkeeping is bounded: finished records were handed off
    assert not eng.finished and not drv._streams


def test_stream_matches_run_tp2():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(1), CFG, (5, 7, 6, 8, 5))
    base = _batch_reference(CFG, params, prompts, 6, slots=2, max_len=64,
                            paged=True)
    [mesh] = replica_meshes(1, 2)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mesh=mesh)
    out, _ = _driver_outputs(eng, prompts, 6)
    assert out == base
    assert eng.tp == 2
    assert eng.stats["decode_traces"] == 1


def test_stream_matches_run_dp2():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(2), CFG, (5, 7, 6, 8, 5, 4))
    base = _batch_reference(CFG, params, prompts, 6, slots=2, max_len=64,
                            paged=True)
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True)
    out, _ = _driver_outputs(router, prompts, 6)
    assert out == base
    assert all(r["decode_traces"] == 1
               for r in router.stats["replicas"])


def test_live_submit_while_running():
    """Requests arriving while the loop is already stepping still finish
    with batch-identical greedy output (per-slot decode is independent of
    co-residents, so admission timing cannot change tokens)."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(3), CFG, (5, 7, 6))
    base = _batch_reference(CFG, params, prompts, 5, slots=2, max_len=64,
                            paged=True)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)
    with AsyncDriver(eng) as drv:
        streams = []
        for i, p in enumerate(prompts):
            streams.append(drv.submit(p, max_new=5, rid=i))
            time.sleep(0.01)                    # interleave with stepping
        out = {s.rid: list(s.result(timeout=60.0).out) for s in streams}
    assert out == base


# ------------------------------------------------- engine stats hooks

def test_reset_stats_keeps_trace_counters():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(4), CFG, (5, 7))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=4)
    eng.run()
    st = eng.stats
    assert st["step_count"] > 0
    assert st["decode_tokens"] >= 2 * 4         # prefill emits 1 + decodes
    assert st["wall_time_s"] > 0.0
    assert st["tokens_per_s_ewma"] > 0.0
    eng.reset_stats()
    st = eng.stats
    assert st["step_count"] == 0 and st["decode_steps"] == 0
    assert st["wall_time_s"] == 0.0 and st["decode_tokens"] == 0
    # program identity is lifetime-monotonic: traces survive the reset
    # (the mixed step runs prefill chunks through the decode program, so
    # prefill_traces stays 0 on the paged default)
    assert st["decode_traces"] == 1 and st["prefill_traces"] == 0
    for i, p in enumerate(prompts):
        eng.submit(10 + i, p, max_new=4)
    eng.run()
    assert eng.stats["decode_traces"] == 1      # steady state: no retrace


def test_router_stats_aggregate_and_reset():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(5), CFG, (5, 7, 6, 8))
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True)
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=4)
    router.run()
    st = router.stats
    per = st["replicas"]
    # sums across disjoint replicas, no double counting
    assert st["step_count"] == sum(r["step_count"] for r in per)
    assert st["decode_tokens"] == sum(r["decode_tokens"] for r in per)
    assert st["tokens_per_s_ewma"] == pytest.approx(
        sum(r["tokens_per_s_ewma"] for r in per))
    router.reset_stats()
    st = router.stats
    assert st["step_count"] == 0
    assert all(r["decode_traces"] == 1 for r in st["replicas"])


def test_router_latency_aware_routing():
    """With EWMAs populated, the router scores load/rate: the 4x-faster
    replica absorbs the new request even at equal queue depth; with any
    replica still cold (rate 0) the queue-depth fallback decides."""
    params = _params(CFG)
    router = ReplicaRouter(CFG, params, dp=2, slots=1, max_len=64,
                           paged=True)
    p = np.arange(5, dtype=np.int32) % CFG.vocab_size
    # cold start: no replica has decoded -> least queue depth (replica 0)
    assert router.route(p) == 0
    router.engines[0].stats["tokens_per_s_ewma"] = 10.0
    assert router.route(p) == 0                 # replica 1 still cold
    # both warm, equal load: drain-time tiebreak prefers the fast one
    router.engines[1].stats["tokens_per_s_ewma"] = 40.0
    router.engines[0].submit(0, p, max_new=4)
    router.engines[1].submit(1, p, max_new=4)
    assert router.route(p) == 1                 # 1/40 < 1/10 drain time


def test_decode_blocks_register_into_prefix_cache():
    """Completed decode pages join the prefix cache: replaying a
    prompt+output context hits blocks that were produced by DECODE, not
    prefill."""
    params = _params(CFG)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=True,
                      page_size=8, prefix_cache=True)
    prompt = (np.arange(8) % CFG.vocab_size).astype(np.int32)
    eng.submit(0, prompt, max_new=17)           # crosses pos 16 and 24
    out = eng.run()[0].out
    assert eng.stats["prefix_decode_blocks"] >= 2
    # replay the full generated context: its second+third blocks exist
    # ONLY because decode registered them
    replay = np.concatenate([prompt, np.asarray(out[:16], np.int32)])
    hits0 = eng.stats["prefix_hit_blocks"]
    eng.submit(1, replay, max_new=2)
    eng.run()
    assert eng.stats["prefix_hit_blocks"] - hits0 >= 3


# ----------------------------------------------------------- watchdog

def test_watchdog_detects_injected_stall(caplog):
    """A stalled step fires the watchdog within the timeout: diagnostics
    dumped at ERROR, every active slot cancelled-and-requeued, and the
    request still completes with batch-identical output."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(7), CFG, (6,))
    base = _batch_reference(CFG, params, prompts, 8, slots=2, max_len=64,
                            paged=True)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)
    # warm the engine (trace prefill + decode) so the short watchdog
    # deadline below can only be crossed by the INJECTED stall
    eng.submit(100, prompts[0], max_new=2)
    eng.run()

    calls = {"n": 0, "stall_id": None}

    def step_fn(drv):
        calls["n"] += 1
        if calls["n"] == 2:                     # rid 0 is mid-decode now
            # the id the stalled step WOULD get (what the dump must name)
            calls["stall_id"] = eng.stats["step_count"]
            deadline = time.monotonic() + 20.0
            while not drv.abort_step.is_set() and \
                    time.monotonic() < deadline:
                time.sleep(0.01)
            return                              # stalled step yields
        drv.engine.step()

    drv = AsyncDriver(eng, watchdog_timeout=0.25, step_fn=step_fn,
                      start=False)
    with caplog.at_level(logging.ERROR, logger="repro.serve"):
        stream = drv.submit(prompts[0], max_new=8, rid=0)
        t0 = time.monotonic()
        drv.start()
        rec = stream.result(timeout=60.0)
        drv.stop(drain=True)
    assert rec.done and list(rec.out) == base[0]
    assert drv.metrics.watchdog_fired.value >= 1
    assert drv.metrics.watchdog_requeued.value >= 1
    assert eng.stats["preemptions"] >= 1        # recovery used the
    #                                             engine's existing path
    text = caplog.text
    # flight-recorder dump content: the stalled STEP ID by number, the
    # active slot row (slot id + rid), and pool occupancy
    m = re.search(r"step (\d+) stalled", text)
    assert m, text
    assert int(m.group(1)) == calls["stall_id"]
    assert re.search(r"slot r0/s\d+: rid=0", text)
    assert re.search(r"pool r0: \d+ pages in use, \d+ free", text)
    # ... plus the step-record ring tail with per-phase timings
    assert re.search(r"flight r0 step \d+:", text)
    assert "dispatch=" in text
    assert "requeued 1 active request(s)" in text
    # detection latency: fired well within a few timeouts of the stall
    assert time.monotonic() - t0 < 20.0
    assert not drv.abort_step.is_set()          # recovery cleared it


# ---------------------------------------------------------- HTTP layer

def _get(url):
    with urllib.request.urlopen(url, timeout=30) as r:
        return r.read().decode()


def _post(url, obj):
    req = urllib.request.Request(
        url, data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    return urllib.request.urlopen(req, timeout=60)


def test_http_endpoints_over_socket():
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(8), CFG, (5, 7))
    base = _batch_reference(CFG, params, prompts, 6, slots=2, max_len=64,
                            paged=True)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)
    with serve_http(eng, port=0) as server:
        # non-streaming generate: full record in one JSON response
        with _post(f"{server.url}/generate",
                   {"prompt": [int(t) for t in prompts[0]],
                    "max_new": 6}) as r:
            body = json.loads(r.read())
        assert body["done"] is True
        assert body["tokens"] == base[0]

        # streaming generate: chunked JSON lines, one per token
        with _post(f"{server.url}/generate",
                   {"prompt": [int(t) for t in prompts[1]],
                    "max_new": 6, "stream": True}) as r:
            assert r.headers["Transfer-Encoding"] == "chunked"
            lines = [json.loads(ln) for ln in r if ln.strip()]
        *toks, closing = lines
        assert [ln["token"] for ln in toks] == base[1]
        assert [ln["index"] for ln in toks] == list(range(6))
        assert closing["done"] is True and closing["tokens"] == base[1]

        # metrics scrape: TTFT/TPOT summaries + engine telemetry gauges
        metrics = _get(f"{server.url}/metrics")
        for name in ("serve_ttft_seconds", "serve_tpot_seconds"):
            for q in ("0.5", "0.9", "0.99"):
                assert f'{name}{{quantile="{q}"}}' in metrics
        assert "serve_requests_completed_total 2.0" in metrics
        assert "serve_engine_pages_in_use" in metrics
        assert "serve_engine_preemptions" in metrics

        # health probe
        health = json.loads(_get(f"{server.url}/healthz"))
        assert health["status"] == "ok"
        assert health["step_count"] > 0

        # validation failures are 400 with the reason, not a wedged socket
        for bad in ({"max_new": 4},             # no prompt
                    {"prompt": ["a", "b"]},     # not token ids
                    {"prompt": []},             # engine rejects empty
                    {"prompt": [1, 2],          # non-numeric timeout is a
                     "timeout": "soon"}):       # bad field, not a 500
            with pytest.raises(urllib.error.HTTPError) as ei:
                _post(f"{server.url}/generate", bad)
            assert ei.value.code == 400

        # unknown routes
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{server.url}/nope")
        assert ei.value.code == 404


def test_generate_without_timeout_504s_on_stall():
    """A non-streaming /generate with NO client "timeout" used to block
    its handler thread forever when the engine wedged. The server now
    caps the wait (result_timeout -> watchdog timeout -> 300s default)
    and answers 504 — the socket comes back, the thread is released."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(9), CFG, (5,))
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True)

    def stalled_step(drv):
        # a wedged engine: every step burns wall time and produces no
        # tokens (each call returns, so submits still enqueue — the
        # request just never completes)
        time.sleep(0.05)

    drv = AsyncDriver(eng, step_fn=stalled_step)
    try:
        with ServeHTTPServer(drv, port=0, result_timeout=0.5) as server:
            t0 = time.monotonic()
            with pytest.raises(urllib.error.HTTPError) as ei:
                _post(f"{server.url}/generate",
                      {"prompt": [int(t) for t in prompts[0]],
                       "max_new": 4})           # note: no "timeout"
            assert ei.value.code == 504
            body = json.loads(ei.value.read())
            assert "rid" in body and "error" in body
            # bounded by the server cap, not DEFAULT_RESULT_TIMEOUT_S
            assert time.monotonic() - t0 < 30.0
            # an explicit client timeout still wins over the server cap
            t0 = time.monotonic()
            with pytest.raises(urllib.error.HTTPError) as ei:
                _post(f"{server.url}/generate",
                      {"prompt": [int(t) for t in prompts[0]],
                       "max_new": 4, "timeout": 0.1})
            assert ei.value.code == 504
            assert time.monotonic() - t0 < 30.0
    finally:
        drv.stop(drain=False)
