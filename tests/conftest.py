import os

# smoke tests and benches must see the REAL device count (1), never 512 —
# the forced-512 flag belongs exclusively to launch/dryrun.py. Some tests
# build small multi-device meshes; they request 8 CPU devices explicitly.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
