"""Property tests (hypothesis): the paged-KV free-list allocator under
random admission/extend/free churn, checked op-by-op against a pure-Python
reference model. Invariants (exclusive-ownership churn, no sharing ops):

  * no page is ever owned by two live owners;
  * every page an owner held returns to the free-list on free();
  * pages_in_use == sum(ceil(len_i / page_size)) over live owners;
  * alloc/extend fail (None) exactly when the free-list is too short —
    uniform pages cannot fragment.

A second suite churns the SHARING ops (adopt-on-alloc, raw ref/deref,
copy-on-write) against a reference refcount model:

  * refcount conservation — every live page's refcount equals the number
    of owners listing it plus raw cache references, and pages_in_use
    equals the count of UNIQUE live pages (free + unique == pool);
  * no double-free — a page returns to the free-list exactly when its
    last reference drops, never while an owner or the cache still holds
    it;
  * writer isolation after CoW — the writer ends with a refcount-1
    private page, every other holder still lists the original.

(The non-hypothesis seeded churn variants live in test_serve_paged.py so
the invariants keep local coverage when hypothesis is absent.)
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.serve.paging import PageAllocator, pages_for  # noqa: E402


class RefModel:
    """Reference bookkeeping: just (owner -> token length)."""

    def __init__(self, num_pages, page_size):
        self.num_pages, self.page_size = num_pages, page_size
        self.lens = {}

    def pages_in_use(self):
        return sum(pages_for(n, self.page_size) for n in self.lens.values())

    def can_add(self, extra_pages):
        return self.pages_in_use() + extra_pages <= self.num_pages


def check_invariants(alloc: PageAllocator, ref: RefModel):
    owned = [p for o in list(alloc.owners()) for p in alloc.pages_of(o)]
    # no page owned twice
    assert len(owned) == len(set(owned)), owned
    # ids stay inside the pool range
    lo, hi = alloc.first_page, alloc.first_page + alloc.num_pages
    assert all(lo <= p < hi for p in owned), owned
    # conservation: free + owned == pool
    assert alloc.free_pages + len(owned) == alloc.num_pages
    # in-use == sum of per-owner ceil(len / page_size)
    assert alloc.pages_in_use == ref.pages_in_use()
    assert set(alloc.owners()) == set(ref.lens)


OPS = hst.lists(
    hst.tuples(hst.sampled_from(["alloc", "extend", "free"]),
               hst.integers(0, 4),          # owner (slot) id
               hst.integers(0, 50)),        # token count / growth
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=OPS, num_pages=hst.integers(1, 12), page_size=hst.integers(1, 8))
def test_allocator_churn_matches_reference(ops, num_pages, page_size):
    alloc = PageAllocator(num_pages, page_size, first_page=1)
    ref = RefModel(num_pages, page_size)
    for op, owner, n in ops:
        if op == "alloc":
            if owner in ref.lens:
                with pytest.raises(ValueError):
                    alloc.alloc(owner, n)
            else:
                got = alloc.alloc(owner, n)
                want_ok = ref.can_add(pages_for(n, page_size))
                assert (got is not None) == want_ok, (op, owner, n)
                if got is not None:
                    ref.lens[owner] = n
                    assert len(got) == pages_for(n, page_size)
        elif op == "extend":
            if owner not in ref.lens:
                # regression: a lookup failure, never a fresh owner entry
                with pytest.raises(KeyError):
                    alloc.extend(owner, n)
            else:
                new_len = ref.lens[owner] + n
                extra = (pages_for(new_len, page_size)
                         - pages_for(ref.lens[owner], page_size))
                got = alloc.extend(owner, new_len)
                assert (got is not None) == ref.can_add(extra)
                if got is not None:
                    ref.lens[owner] = new_len
                    assert len(got) == extra
        else:  # free
            if owner not in ref.lens:
                with pytest.raises(ValueError):
                    alloc.free(owner)
            else:
                before = alloc.free_pages
                freed = alloc.free(owner)
                assert len(freed) == pages_for(ref.lens.pop(owner),
                                               page_size)
                assert alloc.free_pages == before + len(freed)
        check_invariants(alloc, ref)


# ------------------------------------------------- sharing / refcount / CoW

class ShareRefModel:
    """Reference refcount bookkeeping, mirrored from allocator RETURNS:
    owner -> block-ordered page list, plus raw cache references."""

    def __init__(self, num_pages):
        self.num_pages = num_pages
        self.owners = {}
        self.cache = {}                    # page -> raw ref count

    def live(self):
        pages = {p for ps in self.owners.values() for p in ps}
        pages |= {p for p, c in self.cache.items() if c > 0}
        return pages

    def rc(self, page):
        return (sum(ps.count(page) for ps in self.owners.values())
                + self.cache.get(page, 0))

    def free(self):
        return self.num_pages - len(self.live())


def check_share_invariants(alloc: PageAllocator, ref: ShareRefModel):
    live = ref.live()
    # unique-live conservation: free + unique live pages == pool
    assert alloc.pages_in_use == len(live)
    assert alloc.free_pages == ref.free()
    # refcount conservation: owners' listings + raw refs, page by page
    assert alloc.refcounts() == {p: ref.rc(p) for p in live}
    for o, pages in ref.owners.items():
        assert alloc.pages_of(o) == pages
    assert set(alloc.owners()) == set(ref.owners)


SHARE_OPS = hst.lists(
    hst.tuples(hst.sampled_from(["alloc", "extend", "free", "ref",
                                 "deref", "cow"]),
               hst.integers(0, 3),          # owner id
               hst.integers(0, 30),         # token count / growth
               hst.integers(0, 3),          # donor owner (alloc sharing)
               hst.integers(0, 6)),         # shared-prefix len / block idx
    min_size=1, max_size=70)


@settings(max_examples=60, deadline=None)
@given(ops=SHARE_OPS, num_pages=hst.integers(1, 10),
       page_size=hst.integers(1, 4))
def test_refcounted_sharing_churn_matches_reference(ops, num_pages,
                                                    page_size):
    alloc = PageAllocator(num_pages, page_size, first_page=1)
    ref = ShareRefModel(num_pages)
    for op, owner, n, donor, k in ops:
        if op == "alloc":
            if owner in ref.owners:
                with pytest.raises(ValueError):
                    alloc.alloc(owner, n)
                continue
            want = pages_for(n, page_size)
            shared = ref.owners.get(donor, [])[:min(k, want)]
            got = alloc.alloc(owner, n, shared=shared)
            ok = want - len(shared) <= ref.free()
            assert (got is not None) == ok, (op, owner, n, shared)
            if got is not None:
                assert got[:len(shared)] == list(shared)   # adopted head
                assert len(got) == want
                ref.owners[owner] = list(got)
        elif op == "extend":
            if owner not in ref.owners:
                with pytest.raises(KeyError):
                    alloc.extend(owner, n)
                continue
            held = len(ref.owners[owner])
            new_len = held * page_size + n     # never shrinks
            extra = pages_for(new_len, page_size) - held
            got = alloc.extend(owner, new_len)
            assert (got is not None) == (extra <= ref.free())
            if got is not None:
                ref.owners[owner].extend(got)
        elif op == "free":
            if owner not in ref.owners:
                with pytest.raises(ValueError):
                    alloc.free(owner)
            else:
                freed = alloc.free(owner)
                assert freed == ref.owners.pop(owner)
        elif op == "ref":
            pages = ref.owners.get(owner)
            if not pages:
                continue
            p = pages[k % len(pages)]
            alloc.ref(p)                       # cache pins a block
            ref.cache[p] = ref.cache.get(p, 0) + 1
        elif op == "deref":
            pinned = sorted(p for p, c in ref.cache.items() if c > 0)
            if not pinned:
                continue
            p = pinned[k % len(pinned)]
            alloc.deref(p)                     # cache evicts a block
            ref.cache[p] -= 1
        else:  # cow
            pages = ref.owners.get(owner)
            if not pages:
                continue
            blk = k % len(pages)
            old = pages[blk]
            was_shared = ref.rc(old) > 1
            got = alloc.cow(owner, blk)
            if not was_shared:
                assert got == old              # already private: no-op
            elif ref.free() > 0:
                # writer isolation: a fresh private page for the writer,
                # the shared original keeps its other holders
                assert got is not None and got != old
                assert got not in ref.live()
                pages[blk] = got
                assert alloc.refcount(got) == 1
                assert alloc.refcount(old) == ref.rc(old)
            else:
                assert got is None             # pool dry: caller reclaims
        check_share_invariants(alloc, ref)


@settings(max_examples=40, deadline=None)
@given(lens=hst.lists(hst.integers(0, 33), min_size=1, max_size=8),
       page_size=hst.integers(1, 8))
def test_full_drain_restores_pool(lens, page_size):
    """Admit-all / free-all round trip leaves the pool exactly full."""
    total = sum(pages_for(n, page_size) for n in lens)
    alloc = PageAllocator(max(total, 1), page_size)
    for i, n in enumerate(lens):
        assert alloc.alloc(i, n) is not None
    assert alloc.pages_in_use == total
    for i in range(len(lens)):
        alloc.free(i)
    assert alloc.free_pages == alloc.num_pages
    assert alloc.pages_in_use == 0
