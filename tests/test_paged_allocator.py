"""Property tests (hypothesis): the paged-KV free-list allocator under
random admission/extend/free churn, checked op-by-op against a pure-Python
reference model. Invariants:

  * no page is ever owned by two live owners;
  * every page an owner held returns to the free-list on free();
  * pages_in_use == sum(ceil(len_i / page_size)) over live owners;
  * alloc/extend fail (None) exactly when the free-list is too short —
    uniform pages cannot fragment.

(The non-hypothesis seeded churn variant lives in test_serve_paged.py so
the invariants keep local coverage when hypothesis is absent.)
"""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.serve.paging import PageAllocator, pages_for  # noqa: E402


class RefModel:
    """Reference bookkeeping: just (owner -> token length)."""

    def __init__(self, num_pages, page_size):
        self.num_pages, self.page_size = num_pages, page_size
        self.lens = {}

    def pages_in_use(self):
        return sum(pages_for(n, self.page_size) for n in self.lens.values())

    def can_add(self, extra_pages):
        return self.pages_in_use() + extra_pages <= self.num_pages


def check_invariants(alloc: PageAllocator, ref: RefModel):
    owned = [p for o in list(alloc.owners()) for p in alloc.pages_of(o)]
    # no page owned twice
    assert len(owned) == len(set(owned)), owned
    # ids stay inside the pool range
    lo, hi = alloc.first_page, alloc.first_page + alloc.num_pages
    assert all(lo <= p < hi for p in owned), owned
    # conservation: free + owned == pool
    assert alloc.free_pages + len(owned) == alloc.num_pages
    # in-use == sum of per-owner ceil(len / page_size)
    assert alloc.pages_in_use == ref.pages_in_use()
    assert set(alloc.owners()) == set(ref.lens)


OPS = hst.lists(
    hst.tuples(hst.sampled_from(["alloc", "extend", "free"]),
               hst.integers(0, 4),          # owner (slot) id
               hst.integers(0, 50)),        # token count / growth
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(ops=OPS, num_pages=hst.integers(1, 12), page_size=hst.integers(1, 8))
def test_allocator_churn_matches_reference(ops, num_pages, page_size):
    alloc = PageAllocator(num_pages, page_size, first_page=1)
    ref = RefModel(num_pages, page_size)
    for op, owner, n in ops:
        if op == "alloc":
            if owner in ref.lens:
                with pytest.raises(ValueError):
                    alloc.alloc(owner, n)
            else:
                got = alloc.alloc(owner, n)
                want_ok = ref.can_add(pages_for(n, page_size))
                assert (got is not None) == want_ok, (op, owner, n)
                if got is not None:
                    ref.lens[owner] = n
                    assert len(got) == pages_for(n, page_size)
        elif op == "extend":
            if owner not in ref.lens:
                with pytest.raises(ValueError):
                    alloc.extend(owner, n)
            else:
                new_len = ref.lens[owner] + n
                extra = (pages_for(new_len, page_size)
                         - pages_for(ref.lens[owner], page_size))
                got = alloc.extend(owner, new_len)
                assert (got is not None) == ref.can_add(extra)
                if got is not None:
                    ref.lens[owner] = new_len
                    assert len(got) == extra
        else:  # free
            if owner not in ref.lens:
                with pytest.raises(ValueError):
                    alloc.free(owner)
            else:
                before = alloc.free_pages
                freed = alloc.free(owner)
                assert len(freed) == pages_for(ref.lens.pop(owner),
                                               page_size)
                assert alloc.free_pages == before + len(freed)
        check_invariants(alloc, ref)


@settings(max_examples=40, deadline=None)
@given(lens=hst.lists(hst.integers(0, 33), min_size=1, max_size=8),
       page_size=hst.integers(1, 8))
def test_full_drain_restores_pool(lens, page_size):
    """Admit-all / free-all round trip leaves the pool exactly full."""
    total = sum(pages_for(n, page_size) for n in lens)
    alloc = PageAllocator(max(total, 1), page_size)
    for i, n in enumerate(lens):
        assert alloc.alloc(i, n) is not None
    assert alloc.pages_in_use == total
    for i in range(len(lens)):
        alloc.free(i)
    assert alloc.free_pages == alloc.num_pages
    assert alloc.pages_in_use == 0
