"""Speculative multi-token decode (serve/speculative.py riding the mixed
token-slot step). The contract pinned here:

  * greedy outputs with ``spec=`` on are BIT-IDENTICAL to the same
    engine with speculation off — dense (multi-slot), MoE (no-drop
    capacity), enc-dec (frames), prefix-cache + lazy CoW sharing, both
    paged-attention backends, and the tp2/dp2 sharded layouts — because
    every emitted token is the verifier's own argmax at its position;
  * the draft rows ride the EXISTING mixed program: decode_traces stays
    bounded by (token-budget, page-bucket) shapes, spec on or off;
  * on repetitive context the prompt-lookup drafter accepts >1 token
    per (step, slot) — the whole point of drafting;
  * EOS / ``max_new`` landing INSIDE an accepted draft truncate the
    output exactly (min(max_new, tokens-until-EOS) — never a token
    beyond the stop);
  * rejection rollback is exact page bookkeeping: reservations shrink
    back to the accepted cursor (``PageAllocator.rollback``), the pool
    drains clean after the run, and drafted writes never corrupt
    prefix-shared pages (CoW isolates the base block; draft blocks are
    always extend-fresh private pages).
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.paging import PageAllocator
from repro.serve.parallel import ReplicaRouter, replica_meshes
from repro.serve.speculative import (DraftModelDrafter, NgramDrafter,
                                     SpecConfig)

CFG = ModelConfig(name="spec-dense", arch_type="dense", num_layers=2,
                  d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                  vocab_size=128, dtype="float32")

# capacity_factor = E / k: no-drop dispatch — batch contents (draft rows
# present or not) cannot perturb expert routing, so spec on/off stays
# bit-identical (the same regime the mixed/split identity tests pin)
MOE_CFG = ModelConfig(name="spec-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2,
                      moe_capacity_factor=2.0, vocab_size=128,
                      dtype="float32")

AUDIO_CFG = ModelConfig(name="spec-encdec", arch_type="audio",
                        num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=4, d_ff=128, vocab_size=128,
                        encoder_layers=1, encoder_ctx=12, dtype="float32")

SPEC = SpecConfig(k=4)


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _prompts(rng, cfg, lens):
    return [rng.integers(0, cfg.vocab_size, size=(int(n),)).astype(np.int32)
            for n in lens]


def _repetitive_prompts(rng, cfg, n_prompts):
    """Tiled short motifs — the prompt-lookup drafter's best case (the
    same shape bench_serve_throughput.py --repetitive drives)."""
    out = []
    for _ in range(n_prompts):
        motif = rng.integers(0, cfg.vocab_size,
                             size=(int(rng.integers(3, 6)),))
        out.append(np.tile(motif, int(rng.integers(4, 7)))
                   .astype(np.int32))
    return out


def _serve(cfg, params, prompts, new, *, spec=None, frames=None,
           mesh=None, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("chunk_tokens", 16)
    eng = ServeEngine(cfg, params, mesh=mesh, paged=True, mixed=True,
                      spec=spec, **kw)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new,
                   frames=None if frames is None else frames[i])
    results = eng.run()
    return {i: list(results[i].out) for i in results}, eng


# ------------------------------------------------------- drafter units

def test_ngram_drafter_matches_most_recent_longest():
    d = NgramDrafter(ngram_min=1, ngram_max=4)
    # trailing [1,2,3] recurs at the start; continuation is [4,1]
    got = d.propose(np.array([1, 2, 3, 4, 1, 2, 3]), 2)
    assert got.tolist() == [4, 1]
    # most recent match wins: trailing [7] last recurs before 9
    got = d.propose(np.array([7, 8, 7, 9, 7]), 3)
    assert got.tolist() == [9, 7]
    # proposal truncates at the context end and at k
    got = d.propose(np.array([5, 6, 5]), 4)
    assert got.tolist() == [6, 5]


def test_ngram_drafter_no_match_is_empty():
    d = NgramDrafter()
    assert d.propose(np.array([1, 2, 3]), 4).size == 0
    assert d.propose(np.array([9]), 4).size == 0
    # ngram_min above every recurring length: no draft either
    d2 = NgramDrafter(ngram_min=3, ngram_max=4)
    assert d2.propose(np.array([7, 8, 7, 9, 7]), 3).size == 0


def test_draft_model_drafter_is_own_greedy_chain():
    """With the verifier's own params the draft model's proposals are
    its teacher-forced greedy continuation — position i's argmax feeds
    position i+1."""
    params = _params(CFG)
    d = DraftModelDrafter(CFG, params, max_len=64)
    ctx = _prompts(np.random.default_rng(0), CFG, (7,))[0]
    got = d.propose(ctx, 3)
    assert got.shape == (3,)
    # replay manually: forward over ctx + accepted drafts, argmax each
    run = list(ctx)
    for i in range(3):
        logits = get_model(CFG).forward(
            params, {"tokens": np.asarray(run, np.int32)[None]}, CFG)[0]
        t = int(np.argmax(np.asarray(logits)[0, -1]))
        assert int(got[i]) == t
        run.append(t)
    # k clamps to the drafter's max_len headroom
    assert d.propose(np.arange(62) % CFG.vocab_size, 4).shape == (2,)


def test_spec_config_validation():
    with pytest.raises(ValueError, match="spec.k"):
        SpecConfig(k=0)
    with pytest.raises(ValueError, match="drafter"):
        SpecConfig(drafter="oracle")
    with pytest.raises(ValueError, match="ngram_min"):
        SpecConfig(ngram_min=3, ngram_max=2)


def test_engine_rejects_bad_spec_combinations():
    params = _params(CFG)
    with pytest.raises(ValueError, match="mixed"):
        ServeEngine(CFG, params, paged=True, mixed=False, spec=SPEC)
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(CFG, params, paged=True, mixed=True, spec=SPEC,
                    temperature=0.7, chunk_tokens=32)
    with pytest.raises(ValueError, match="chunk_tokens"):
        ServeEngine(CFG, params, paged=True, mixed=True, spec=SPEC,
                    slots=4, chunk_tokens=8)


# ------------------------------------------------- greedy bit-identity

def test_spec_matches_plain_dense_multislot():
    params = _params(CFG)
    rng = np.random.default_rng(0)
    prompts = _prompts(rng, CFG, (5, 23, 9)) + \
        _repetitive_prompts(rng, CFG, 2)
    plain, _ = _serve(CFG, params, prompts, 8)
    spec, se = _serve(CFG, params, prompts, 8, spec=SPEC)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0
    # draft rows ride the one mixed program: no extra trace shapes
    assert se.stats["prefill_traces"] == 0
    assert se.stats["decode_traces"] <= 2


def test_spec_matches_plain_moe():
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(5)
    prompts = _prompts(rng, MOE_CFG, (5, 19)) + \
        _repetitive_prompts(rng, MOE_CFG, 2)
    plain, _ = _serve(MOE_CFG, params, prompts, 6)
    spec, se = _serve(MOE_CFG, params, prompts, 6, spec=SPEC)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0


def test_spec_matches_plain_encdec():
    params = _params(AUDIO_CFG, seed=2)
    rng = np.random.default_rng(2)
    prompts = _prompts(rng, AUDIO_CFG, (4, 9)) + \
        _repetitive_prompts(rng, AUDIO_CFG, 2)
    frames = [rng.standard_normal(
        (AUDIO_CFG.encoder_ctx, AUDIO_CFG.d_model)).astype(np.float32)
        for _ in prompts]
    plain, _ = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                      max_len=48)
    spec, se = _serve(AUDIO_CFG, params, prompts, 5, frames=frames,
                      max_len=48, spec=SPEC)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0


def test_spec_matches_plain_prefix_cache_lazy():
    """Shared system prompt + lazy growth: drafted KV writes land on
    extend-fresh private pages (base block CoW'd first), so the shared
    prefix stays byte-stable — the second adopter's output would diverge
    otherwise."""
    params = _params(CFG)
    rng = np.random.default_rng(3)
    system = rng.integers(0, CFG.vocab_size, size=(33,)).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size, size=(int(n),))])
        .astype(np.int32) for n in (5, 9, 3, 14)]
    kw = dict(slots=4, prefix_cache=True, lazy=True, chunk_tokens=24)
    plain, pe = _serve(CFG, params, prompts, 6, **kw)
    spec, se = _serve(CFG, params, prompts, 6, spec=SPEC, **kw)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0
    # sharing still collapses the system prompt to one physical copy
    assert se.stats["prefix_hit_blocks"] >= pe.stats["prefix_hit_blocks"]


def test_spec_matches_plain_pallas_backend():
    params = _params(CFG)
    rng = np.random.default_rng(11)
    prompts = _prompts(rng, CFG, (5, 17)) + _repetitive_prompts(rng, CFG, 2)
    plain, _ = _serve(CFG, params, prompts, 6, attn_backend="pallas")
    spec, se = _serve(CFG, params, prompts, 6, attn_backend="pallas",
                      spec=SPEC)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0
    assert se.stats["decode_backend"] == "pallas"


def test_spec_matches_plain_tp2_dp2():
    params = _params(CFG)
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, CFG, (5, 21)) + _repetitive_prompts(rng, CFG, 2)
    plain, _ = _serve(CFG, params, prompts, 6)
    [mesh] = replica_meshes(1, 2)
    tp2, te = _serve(CFG, params, prompts, 6, mesh=mesh, spec=SPEC)
    assert tp2 == plain
    assert te.stats["spec_drafted"] > 0
    router = ReplicaRouter(CFG, params, dp=2, slots=2, max_len=64,
                           paged=True, mixed=True, chunk_tokens=16,
                           spec=SPEC)
    for i, p in enumerate(prompts):
        router.submit(i, p, max_new=6)
    res = router.run()
    assert {i: list(res[i].out) for i in res} == plain
    assert router.stats["spec_drafted"] > 0


# ------------------------------------------------ speedup + accounting

def test_repetitive_context_accepts_multiple_tokens_per_step():
    """On tiled-motif prompts prompt-lookup drafting must beat one
    token per (step, decoding slot) — the accounting the driver's
    serve_spec_tokens_per_step summary and the bench column report."""
    params = _params(CFG)
    rng = np.random.default_rng(4)
    prompts = _repetitive_prompts(rng, CFG, 4)
    plain, pe = _serve(CFG, params, prompts, 16)
    spec, se = _serve(CFG, params, prompts, 16, spec=SPEC)
    assert spec == plain

    def per_slot_step(st):
        return (st["decode_tokens"] - st["prefills"]) / \
            max(st["decode_slot_steps"], 1)

    # without speculation the ratio is exactly 1.0 by construction
    assert per_slot_step(pe.stats) == pytest.approx(1.0)
    assert per_slot_step(se.stats) > 1.0
    assert se.stats["spec_accepted"] > 0
    assert se.stats["decode_steps"] < pe.stats["decode_steps"]


def test_driver_exposes_spec_metrics():
    """The async driver observes the engine's speculative counters into
    Prometheus instruments: drafted/accepted totals, the cumulative
    accept-rate gauge, and the per-(step, slot) accepted-tokens summary
    — and stays truthful when a step emits several tokens at once."""
    from repro.serve.driver import AsyncDriver
    params = _params(CFG)
    rng = np.random.default_rng(12)
    prompts = _repetitive_prompts(rng, CFG, 3)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      mixed=True, chunk_tokens=16, spec=SPEC)
    drv = AsyncDriver(eng, start=False)
    streams = [drv.submit(p, max_new=12, rid=i)
               for i, p in enumerate(prompts)]
    drv.start()
    records = {s.rid: s.result(timeout=60.0) for s in streams}
    drv.stop(drain=True)
    assert all(r.done for r in records.values())
    m = drv.metrics
    assert m.spec_drafted.value == eng.stats["spec_drafted"] > 0
    assert m.spec_accepted.value == eng.stats["spec_accepted"] > 0
    assert m.spec_accept_rate.value == pytest.approx(
        eng.stats["spec_accepted"] / eng.stats["spec_drafted"])
    assert m.spec_tokens_per_step.count > 0
    # every request got one TTFT and exactly max_new streamed tokens
    assert m.ttft.count == len(prompts)
    assert all(len(r.out) == 12 for r in records.values())


# ------------------------------------------------- stop-condition edges

def test_eos_inside_accepted_draft():
    """Self-drafting with the verifier's own params accepts essentially
    every draft, so EOS lands mid-chain: output must stop exactly at the
    EOS token — min(max_new, tokens-until-EOS) — token-identical to the
    non-speculative run with the same eos_id."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(9), CFG, (6,))
    base, _ = _serve(CFG, params, prompts, 14)
    # pick a token whose FIRST occurrence is deep enough that, under
    # (near-)full acceptance, it is emitted inside an accepted draft
    eos, at = None, None
    for idx in range(2, len(base[0])):
        if base[0].index(base[0][idx]) == idx:
            eos, at = base[0][idx], idx
            break
    assert eos is not None, "degenerate greedy chain"
    spec = SpecConfig(k=4, drafter="model", draft_cfg=CFG,
                      draft_params=params)
    plain, _ = _serve(CFG, params, prompts, 14, eos_id=eos)
    specr, se = _serve(CFG, params, prompts, 14, eos_id=eos, spec=spec)
    assert specr == plain
    assert specr[0] == base[0][:at + 1]          # nothing past the EOS
    assert se.stats["spec_accepted"] > 0


def test_max_new_inside_accepted_draft():
    """max_new cuts an accepted chain mid-draft: never a surplus token."""
    params = _params(CFG)
    prompts = _prompts(np.random.default_rng(10), CFG, (5, 8))
    spec = SpecConfig(k=4, drafter="model", draft_cfg=CFG,
                      draft_params=params)
    for new in (2, 3, 7):
        plain, _ = _serve(CFG, params, prompts, new)
        specr, _ = _serve(CFG, params, prompts, new, spec=spec)
        assert specr == plain
        assert all(len(o) == new for o in specr.values())


# --------------------------------------------- rollback page bookkeeping

def test_rejection_rollback_across_page_boundary_drains_clean():
    """page_size=4 forces rejected drafts to straddle page boundaries:
    the speculative reservation is rolled back to the accepted cursor
    every step, and after the run every page is back in the free list —
    no leaked draft pages, no stale references."""
    params = _params(CFG)
    rng = np.random.default_rng(7)
    prompts = _prompts(rng, CFG, (5, 11, 7)) + \
        _repetitive_prompts(rng, CFG, 2)
    kw = dict(slots=2, lazy=True, page_size=4, max_len=64)
    plain, _ = _serve(CFG, params, prompts, 10, **kw)
    spec, se = _serve(CFG, params, prompts, 10, spec=SPEC, **kw)
    assert spec == plain
    assert se.stats["spec_drafted"] > se.stats["spec_accepted"]  # rejects
    assert se._alloc.free_pages == se._alloc.num_pages
    assert se._alloc.pages_in_use == 0
    assert list(se._alloc.owners()) == []


def test_drafts_on_cow_shared_pages_leave_prefix_intact():
    """Prefix-shared pages under speculation: every adopter of the
    shared system prompt decodes the same continuation it would without
    drafting — drafted writes never reach a shared page."""
    params = _params(CFG)
    rng = np.random.default_rng(8)
    system = np.tile(rng.integers(0, CFG.vocab_size, size=(4,)), 5) \
        .astype(np.int32)                      # repetitive shared prefix
    prompts = [np.concatenate(
        [system, rng.integers(0, CFG.vocab_size, size=(int(n),))])
        .astype(np.int32) for n in (3, 6, 4)]
    kw = dict(slots=3, prefix_cache=True, lazy=True, page_size=4,
              chunk_tokens=24, max_len=64)
    plain, pe = _serve(CFG, params, prompts, 8, **kw)
    spec, se = _serve(CFG, params, prompts, 8, spec=SPEC, **kw)
    assert spec == plain
    assert se.stats["spec_drafted"] > 0
    assert se.stats["prefix_hit_blocks"] > 0   # sharing actually happened


def test_allocator_rollback_drops_private_tail():
    a = PageAllocator(8, 4)
    a.alloc("s", 10)                           # 3 pages
    assert a.free_pages == 5
    dropped = a.rollback("s", 5)               # keeps 2 pages
    assert len(dropped) == 1 and a.free_pages == 6
    assert len(a.pages_of("s")) == 2
    # the reservation can regrow over the rolled-back range
    assert a.extend("s", 10) is not None
    assert a.free_pages == 5


def test_allocator_rollback_len_only_shrink():
    """Zero pages dropped still lowers the token length, or the next
    extend would trip the no-shrink guard."""
    a = PageAllocator(8, 4)
    a.alloc("s", 10)
    assert a.rollback("s", 9) == []
    assert len(a.pages_of("s")) == 3
    assert a.extend("s", 12) == []             # within the held 3 pages


def test_allocator_rollback_shared_page_stays_live():
    a = PageAllocator(4, 4)
    [p] = a.alloc("s", 4)
    a.ref(p)                                   # e.g. prefix-cache pin
    assert a.rollback("s", 0) == [p]
    assert a.free_pages == 3                   # pin keeps the page live
    a.deref(p)
    assert a.free_pages == 4


def test_allocator_rollback_errors():
    a = PageAllocator(4, 4)
    with pytest.raises(KeyError):
        a.rollback("nobody", 0)
    a.alloc("s", 4)
    with pytest.raises(ValueError, match="use extend"):
        a.rollback("s", 9)
    assert a.rollback("s", 4) == []            # no-op at the reservation
