"""Serving engine: batched slot-table decode produces the same tokens as
sequential greedy decoding with exactly ONE jitted decode program, and the
admission/termination edge cases (max_new=1, EOS at prefill, prompt at
capacity, queue churn, max_steps truncation) are honored.

The termination/capacity edge cases are parametrized over BOTH KV
layouts — dense per-slot rows and the paged block-table pool — since
admission is where the layouts differ (rows vs free-list pages).
test_serve_paged.py holds the paged-specific suite (fragmentation,
allocator invariants, enc-dec serving)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.models import get_model, kvcache
from repro.serve.engine import ServeEngine
from repro.serve.step import greedy_generate, prefill_bucket

CFG = ModelConfig(name="engine-test", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")

SSM_CFG = ModelConfig(name="engine-ssm", arch_type="ssm", num_layers=2,
                      d_model=64, num_heads=0, num_kv_heads=0, d_ff=128,
                      ssm_state=16, ssm_heads=4, ssm_head_dim=16,
                      vocab_size=128, dtype="float32")


def _params(cfg, seed=0):
    return get_model(cfg).init(jax.random.key(seed), cfg)


def _sequential(params, cfg, prompts, new):
    """Reference: each request decoded alone through greedy_generate."""
    out = {}
    for i, p in enumerate(prompts):
        toks = greedy_generate(params, cfg, Strategy(),
                               {"tokens": jnp.asarray(p)[None, :]},
                               steps=new)
        out[i] = [int(t) for t in toks[0]]
    return out


@pytest.mark.parametrize("paged", [False, True])
def test_engine_matches_sequential_greedy_one_trace(paged):
    """Batched-vs-sequential parity across staggered admissions AND the
    one-program property: the whole run traces exactly one decode step and
    at most one prefill per bucket, regardless of slot occupancy — on both
    KV layouts."""
    params = _params(CFG)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 7, 6, 11)]
    new = 6
    expected = _sequential(params, CFG, prompts, new)

    # 2 slots, 5 requests -> forced queueing + slot reuse at mixed depths
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=paged)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new)
    results = eng.run()
    assert set(results) == set(range(len(prompts)))
    for i in expected:
        assert results[i].done
        assert results[i].out == expected[i], (i, results[i].out, expected[i])

    # trace-count probe: one jitted decode program for the whole run
    assert eng.stats["decode_traces"] == 1
    assert eng.stats["decode_steps"] > 0
    # bucketed prefill: lengths (5,9,7,6,11) -> buckets {8,16} -> <=2 traces
    buckets = {prefill_bucket(len(p)) for p in prompts}
    assert eng.stats["prefill_traces"] <= len(buckets)
    assert eng.stats["prefills"] == len(prompts)


def test_engine_one_decode_call_per_step():
    """One engine step() == exactly one device dispatch, whether 1 or all
    slots are occupied. The default mixed step folds admission prefill
    chunks into the SAME program, so an admission-only step counts no
    decode_steps and prefill never traces a separate program."""
    params = _params(CFG)
    eng = ServeEngine(CFG, params, slots=4, max_len=64)
    eng.submit(0, np.arange(5, dtype=np.int32), max_new=8)   # 1 of 4 slots
    eng.step()                       # admission: prefill chunk, no decode
    assert eng.stats["decode_steps"] == 0
    eng.step()
    assert eng.stats["decode_steps"] == 1
    for i in range(1, 4):
        eng.submit(i, np.arange(4 + i, dtype=np.int32), max_new=8)
    eng.step()                       # 1 decode slot + 3 admission chunks
    assert eng.stats["decode_steps"] == 2
    eng.step()                                               # 4 of 4 slots
    assert eng.stats["decode_steps"] == 3
    assert eng.stats["decode_traces"] == 1
    assert eng.stats["prefill_traces"] == 0


def test_engine_ssm_matches_sequential():
    """The slot-table decode is exact for recurrent (attention-free) archs
    too — exact-length prefill path, no buckets."""
    params = _params(SSM_CFG, seed=4)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, SSM_CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 6)]
    expected = _sequential(params, SSM_CFG, prompts, 5)
    eng = ServeEngine(SSM_CFG, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=5)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out, expected[i])
    assert eng.stats["decode_traces"] == 1


def test_engine_swa_ring_matches_sequential():
    """Sliding-window (ring-cache) serving with prompt lengths that are NOT
    multiples of the window stays token-identical to sequential decoding
    (exercises the fit_prefill ring re-alignment)."""
    cfg = CFG.with_(name="engine-swa", sliding_window=8)
    params = _params(cfg, seed=3)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 13, 9)]           # crosses/straddles the window
    expected = _sequential(params, cfg, prompts, 6)
    eng = ServeEngine(cfg, params, slots=2, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=6)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out, expected[i])
    assert eng.stats["decode_traces"] == 1


MOE_CFG = ModelConfig(name="engine-moe", arch_type="moe", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      num_experts=4, experts_per_token=2, vocab_size=128,
                      dtype="float32")


def test_engine_moe_single_slot_matches_sequential():
    """MoE serving: with one slot the decode batch is a single row, so
    capacity-based routing sees the same batch as sequential decoding and
    tokens match exactly. (With >1 slot, rows share expert capacity and
    outputs legitimately depend on co-resident traffic — see the engine
    docstring caveat.)"""
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, MOE_CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 8)]
    expected = _sequential(params, MOE_CFG, prompts, 5)
    eng = ServeEngine(MOE_CFG, params, slots=1, max_len=32)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=5)
    results = eng.run()
    for i in expected:
        assert results[i].out == expected[i], (i, results[i].out, expected[i])


def test_engine_moe_batched_serves_all():
    """MoE with a full slot table: every request completes with in-vocab
    tokens and one decode trace (exactness is per the docstring caveat)."""
    params = _params(MOE_CFG, seed=5)
    rng = np.random.default_rng(6)
    eng = ServeEngine(MOE_CFG, params, slots=3, max_len=32)
    for i in range(5):
        eng.submit(i, rng.integers(0, MOE_CFG.vocab_size,
                                   size=(int(rng.integers(3, 10)),)),
                   max_new=4)
    results = eng.run()
    assert set(results) == set(range(5))
    assert all(r.done for r in results.values())
    assert all(0 <= t < MOE_CFG.vocab_size
               for r in results.values() for t in r.out)
    assert eng.stats["decode_traces"] == 1


@pytest.mark.parametrize("paged", [False, True])
def test_engine_respects_max_len(paged):
    params = _params(CFG, seed=1)
    eng = ServeEngine(CFG, params, slots=1, max_len=12, paged=paged)
    eng.submit(0, np.arange(8, dtype=np.int32), max_new=100)
    out = eng.run()
    assert out[0].done
    assert len(out[0].out) == 12 - 8 + 1   # capacity-bound, not clamped


@pytest.mark.parametrize("paged", [False, True])
def test_prompt_at_capacity_edge(paged):
    """prompt_len == max_len - 1: exactly one position left, so prefill
    token + one decoded token come back and the cache never writes out of
    range (dense: last row; paged: last offset of the last page)."""
    params = _params(CFG, seed=1)
    eng = ServeEngine(CFG, params, slots=1, max_len=12, paged=paged)
    eng.submit(0, np.arange(11, dtype=np.int32), max_new=100)
    out = eng.run()
    assert out[0].done
    assert len(out[0].out) == 2


def test_submit_validates_inputs():
    params = _params(CFG, seed=1)
    eng = ServeEngine(CFG, params, slots=1, max_len=12)
    with pytest.raises(ValueError):                 # prompt_len == max_len
        eng.submit(0, np.arange(12, dtype=np.int32), max_new=4)
    with pytest.raises(ValueError):                 # prompt_len > max_len
        eng.submit(1, np.arange(40, dtype=np.int32), max_new=4)
    with pytest.raises(ValueError):                 # empty prompt
        eng.submit(2, np.zeros((0,), np.int32), max_new=4)
    with pytest.raises(ValueError):                 # max_new < 1
        eng.submit(3, np.arange(4, dtype=np.int32), max_new=0)
    assert not eng.queue                            # nothing was admitted


@pytest.mark.parametrize("paged", [False, True])
def test_max_new_one_emits_exactly_one_token(paged):
    """max_new=1 finishes at admission: one token out, zero decode calls
    (and on the paged layout, its pages are back in the free-list)."""
    params = _params(CFG)
    prompt = np.arange(5, dtype=np.int32)
    first = _sequential(params, CFG, [prompt], 1)[0]
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=paged)
    eng.submit(0, prompt, max_new=1)
    out = eng.run()
    assert out[0].done
    assert out[0].out == first
    assert eng.stats["decode_steps"] == 0
    if paged:
        assert eng._alloc.pages_in_use == 0


@pytest.mark.parametrize("paged", [False, True])
def test_eos_on_prefill_token(paged):
    """EOS sampled at prefill ends the request immediately (len 1, no
    decode), and the slot is free for the next request in the same admit."""
    params = _params(CFG)
    prompt = np.arange(7, dtype=np.int32)
    first = _sequential(params, CFG, [prompt], 1)[0][0]
    eng = ServeEngine(CFG, params, slots=1, max_len=64, eos_id=first,
                      paged=paged)
    eng.submit(0, prompt, max_new=50)
    out = eng.run()
    assert out[0].done
    assert out[0].out == [first]
    assert eng.stats["decode_steps"] == 0


@pytest.mark.parametrize("paged", [False, True])
def test_eos_mid_decode(paged):
    """Output length is exactly min(max_new, tokens-until-EOS)."""
    params = _params(CFG)
    prompt = np.arange(6, dtype=np.int32)
    ref = _sequential(params, CFG, [prompt], 10)[0]
    eos = ref[3]                                    # hit at decode step 3
    eng = ServeEngine(CFG, params, slots=1, max_len=64, eos_id=eos,
                      paged=paged)
    eng.submit(0, prompt, max_new=10)
    out = eng.run()
    assert out[0].done
    assert out[0].out == ref[:4]                    # EOS token included


@pytest.mark.parametrize("paged", [False, True])
def test_run_returns_partials_on_max_steps(paged):
    """Exhausting max_steps surfaces active requests' partial output and
    queued requests' empty output with done=False — nothing vanishes."""
    params = _params(CFG)
    eng = ServeEngine(CFG, params, slots=1, max_len=64, paged=paged)
    eng.submit(0, np.arange(5, dtype=np.int32), max_new=50)
    eng.submit(1, np.arange(6, dtype=np.int32), max_new=50)
    results = eng.run(max_steps=3)
    assert set(results) == {0, 1}
    assert not results[0].done
    # dense/legacy: prefill + first token before step 1, then 3 decode
    # steps; mixed (paged default): step 1 IS the prefill chunk + first
    # token, steps 2-3 decode
    assert len(results[0].out) == (3 if paged else 4)
    assert not results[1].done
    assert results[1].out == []          # never admitted
    # the engine can resume: a later run() finishes both
    results = eng.run()
    assert results[0].done and results[1].done


def test_queue_churn_many_requests_few_slots():
    """3 slots, 10 requests of mixed lengths/budgets: all served, all
    token-identical to sequential decoding."""
    params = _params(CFG, seed=2)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, CFG.vocab_size, size=(int(n),)).astype(np.int32)
               for n in rng.integers(3, 14, size=10)]
    eng = ServeEngine(CFG, params, slots=3, max_len=32)
    budgets = [int(b) for b in rng.integers(1, 7, size=10)]
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        eng.submit(i, p, max_new=b)
    results = eng.run()
    assert set(results) == set(range(10))
    for i, (p, b) in enumerate(zip(prompts, budgets)):
        ref = _sequential(params, CFG, [p], b)[0]
        assert results[i].done
        assert results[i].out == ref, (i, results[i].out, ref)
    assert eng.stats["decode_traces"] == 1


def test_temperature_sampling_reproducible():
    """temperature>0 goes through the shared on-device sampler: valid
    tokens, seed-reproducible, seed-sensitive."""
    params = _params(CFG)
    prompts = [np.arange(5, dtype=np.int32), np.arange(8, dtype=np.int32)]

    def serve(seed):
        eng = ServeEngine(CFG, params, slots=2, max_len=32,
                          temperature=0.8, seed=seed)
        for i, p in enumerate(prompts):
            eng.submit(i, p, max_new=6)
        return {i: r.out for i, r in eng.run().items()}

    a, b, c = serve(0), serve(0), serve(1)
    assert a == b
    assert a != c                        # overwhelmingly likely
    assert all(0 <= t < CFG.vocab_size for out in a.values() for t in out)


def test_prefill_bucket():
    assert prefill_bucket(1) == 8
    assert prefill_bucket(8) == 8
    assert prefill_bucket(9) == 16
    assert prefill_bucket(100) == 128
    assert prefill_bucket(100, cap=64) == 100    # would overflow the cache
    assert prefill_bucket(60, cap=64) == 64


def test_write_kv_vector_positions():
    """Per-row scatter == per-row loop of scalar writes."""
    cache = kvcache.init_kv(3, 8, 2, 4, jnp.float32)
    k_new = jnp.arange(3 * 2 * 4, dtype=jnp.float32).reshape(3, 1, 2, 4)
    v_new = -k_new
    pos = jnp.asarray([0, 5, 7], jnp.int32)
    got = kvcache.write_kv(dict(cache), k_new, v_new, pos)
    want = dict(cache)
    for b in range(3):
        row = kvcache.write_kv(
            {"k": want["k"][b:b + 1], "v": want["v"][b:b + 1]},
            k_new[b:b + 1], v_new[b:b + 1], pos[b])
        want = {"k": want["k"].at[b].set(row["k"][0]),
                "v": want["v"].at[b].set(row["v"][0])}
    assert jnp.array_equal(got["k"], want["k"])
    assert jnp.array_equal(got["v"], want["v"])
    # ring variant
    got_r = kvcache.write_kv(dict(cache), k_new, v_new,
                             jnp.asarray([3, 9, 17], jnp.int32),
                             ring=True, window=8)
    assert jnp.array_equal(got_r["k"][0, 3], k_new[0, 0])
    assert jnp.array_equal(got_r["k"][1, 1], k_new[1, 0])
    assert jnp.array_equal(got_r["k"][2, 1], k_new[2, 0])


def test_chunked_prefill_exact():
    """Batch-chunked prefill (serve/step.py) is bit-exact vs monolithic."""
    from repro.serve.step import make_prefill_step

    model = get_model(CFG)
    params = model.init(jax.random.key(2), CFG)
    toks = jax.random.randint(jax.random.key(3), (8, 32), 0, CFG.vocab_size)
    lg1, c1 = make_prefill_step(CFG, Strategy(microbatches=1,
                                              dtype="float32"))(
        params, {"tokens": toks})
    lg4, c4 = make_prefill_step(CFG, Strategy(microbatches=4,
                                              dtype="float32"))(
        params, {"tokens": toks})
    assert float(jnp.abs(lg1 - lg4).max()) < 1e-5
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c4)):
        assert float(jnp.abs(a - b).max()) < 1e-5


# ------------------------------------------------------- priority scheduling

def test_priority_policy_unit():
    """Priority.next_index admits the highest priority (FIFO within a
    class); pick_victim evicts lowest priority, then least progress."""
    from collections import deque

    from repro.serve.engine import Request
    from repro.serve.scheduler import FifoLeastProgress, Priority

    pol = Priority()
    q = deque([Request(0, np.arange(4), 4, priority=1),
               Request(1, np.arange(4), 4, priority=5),
               Request(2, np.arange(4), 4, priority=5),
               Request(3, np.arange(4), 4, priority=0)])
    assert pol.next_index(q) == 1          # highest class, earliest within
    assert pol.next_index(deque()) is None
    # victims: (slot, progress, priority)
    assert pol.pick_victim([(0, 9, 2), (1, 0, 5), (2, 3, 2)]) == 2
    assert pol.pick_victim([(0, 9, 2), (1, 0, 2)]) == 1
    # the default policy ignores priority entirely
    assert FifoLeastProgress().pick_victim([(0, 9, 0), (1, 2, 9)]) == 1
    preempted = Request(7, np.arange(4), 4, priority=3)
    pol.requeue(q, preempted)
    assert q[0].rid == 7


def test_priority_admission_order():
    """With one slot, the highest-priority queued request is admitted
    first regardless of submission order — and outputs still match
    sequential decode (admission order never changes greedy tokens)."""
    from repro.serve.scheduler import Priority

    params = _params(CFG)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, CFG.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(3)]
    expected = _sequential(params, CFG, prompts, 4)
    eng = ServeEngine(CFG, params, slots=1, max_len=64,
                      scheduler=Priority())
    for i, pri in enumerate((0, 5, 1)):
        eng.submit(i, prompts[i], max_new=4, priority=pri)
    eng.step()
    assert eng.active[0] is not None and eng.active[0].rid == 1
    results = eng.run()
    assert all(results[i].done for i in range(3))
    assert {i: results[i].out for i in results} == expected


def test_priority_preempts_lowest_priority_first():
    """Lazy growth on a tight pool with the Priority policy: the victim
    is the LOW-priority slot even though it has MORE progress (the
    default least-progress policy would have evicted the high-priority
    newcomer instead), everything still drains, and greedy outputs stay
    exact through the preempt/requeue/resume cycle."""
    from repro.serve.scheduler import Priority

    params = _params(CFG)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(0, CFG.vocab_size, size=(6,)).astype(np.int32)
               for _ in range(2)]
    expected = _sequential(params, CFG, prompts, 10)
    eng = ServeEngine(CFG, params, slots=2, max_len=64, paged=True,
                      page_size=4, kv_pages=6, lazy=True,
                      scheduler=Priority())
    preempted_rids = []
    orig = eng._preempt

    def spy(s):
        preempted_rids.append(eng.active[s].rid)
        orig(s)

    eng._preempt = spy
    # the background request runs alone first: by the time the
    # high-priority one arrives it has strictly more progress
    eng.submit(0, prompts[0], max_new=10, priority=0)
    for _ in range(5):
        eng.step()
    assert eng.active[0] is not None and len(eng.active[0].out) > 1
    eng.submit(1, prompts[1], max_new=10, priority=9)
    results = eng.run()
    assert all(results[i].done for i in range(2))
    assert {i: results[i].out for i in results} == expected
    # joint worst case (8 pages) exceeds the 6-page pool: someone was
    # preempted, and every victim was the low-priority request
    assert eng.stats["preemptions"] >= 1
    assert preempted_rids and all(r == 0 for r in preempted_rids)
