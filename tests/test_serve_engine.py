"""Serving engine: continuous batching produces the same tokens as
sequential greedy decoding, across staggered admissions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.engine import ServeEngine
from repro.serve.step import greedy_generate

CFG = ModelConfig(name="engine-test", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")


def test_engine_matches_sequential_greedy():
    model = get_model(CFG)
    params = model.init(jax.random.key(0), CFG)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 7, 6, 11)]
    new = 6

    # reference: each request decoded alone
    expected = {}
    for i, p in enumerate(prompts):
        out = greedy_generate(params, CFG, Strategy(),
                              {"tokens": jnp.asarray(p)[None, :]},
                              steps=new)
        expected[i] = [int(t) for t in out[0]]

    # engine: 2 slots, 5 requests -> forced queueing + slot reuse
    eng = ServeEngine(CFG, params, slots=2, max_len=64)
    for i, p in enumerate(prompts):
        eng.submit(i, p, max_new=new)
    results = eng.run()
    assert set(results) == set(range(len(prompts)))
    for i in expected:
        assert results[i] == expected[i], (i, results[i], expected[i])


def test_engine_respects_max_len():
    model = get_model(CFG)
    params = model.init(jax.random.key(1), CFG)
    eng = ServeEngine(CFG, params, slots=1, max_len=12)
    eng.submit(0, np.arange(8, dtype=np.int32), max_new=100)
    out = eng.run()
    assert 0 in out
    assert len(out[0]) <= 12 - 8 + 1


def test_chunked_prefill_exact():
    """Batch-chunked prefill (serve/step.py) is bit-exact vs monolithic."""
    import jax.numpy as jnp
    from repro.core.strategy import Strategy
    from repro.serve.step import make_prefill_step

    model = get_model(CFG)
    params = model.init(jax.random.key(2), CFG)
    toks = jax.random.randint(jax.random.key(3), (8, 32), 0, CFG.vocab_size)
    lg1, c1 = make_prefill_step(CFG, Strategy(microbatches=1,
                                              dtype="float32"))(
        params, {"tokens": toks})
    lg4, c4 = make_prefill_step(CFG, Strategy(microbatches=4,
                                              dtype="float32"))(
        params, {"tokens": toks})
    assert float(jnp.abs(lg1 - lg4).max()) < 1e-5
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c4)):
        assert float(jnp.abs(a - b).max()) < 1e-5
