"""repro.api: the plan -> materialize -> Session loop.

Covers the redesign's acceptance points: a searched Plan materializes into
a validated (Strategy, Mesh) pair (including the pp>1 pipeline mesh),
illegal degree/device combinations are rejected, and the Session facade
drives train / generate / serve with params threading through."""
import jax
import numpy as np
import pytest

from repro.api import Degrees, Plan, Session, Strategy, TrainConfig, plan
from repro.configs.base import ModelConfig, ShapeConfig
from repro.serve.engine import ServeEngine

CFG = ModelConfig(name="api-test", arch_type="dense", num_layers=2,
                  d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                  vocab_size=128, dtype="float32")
SHAPE = ShapeConfig("host", 64, 8, "train")


def test_materialize_rejects_degrees_that_dont_tile_devices():
    # conftest forces 8 host devices; dp3 covers 3 chips -> must be refused
    bad = Plan(degrees=Degrees(dp=3, tp=1, pp=1), cost=0.0, mfu=0.0,
               fits=True, evaluations=1, method="manual")
    with pytest.raises(ValueError, match="do not tile"):
        bad.materialize()
    with pytest.raises(ValueError, match="do not tile"):
        bad.materialize(devices=4)
    over = Plan(degrees=Degrees(dp=2, tp=1, pp=1), cost=0.0, mfu=0.0,
                fits=True, evaluations=1, method="manual")
    with pytest.raises(ValueError, match="available"):
        over.materialize(devices=10 * len(jax.devices()))


def test_materialize_pp_plan_builds_pipe_mesh():
    deg = Degrees(dp=2, tp=2, pp=2, microbatches=2)
    p = Plan.from_degrees(CFG, SHAPE, deg)
    strategy, mesh = p.materialize(devices=8)
    assert "pipe" in mesh.axis_names
    assert (mesh.shape["data"], mesh.shape["pipe"], mesh.shape["model"]) \
        == (deg.dp, deg.pp, deg.tp)
    assert strategy.microbatches == deg.microbatches


def test_materialize_single_axis_layout_and_strategy_fields():
    deg = Degrees(dp=4, tp=2, pp=1, microbatches=2, seq_parallel=True,
                  remat=False)
    p = Plan.from_degrees(CFG, SHAPE, deg)
    strategy, mesh = p.materialize(devices=8, dtype="float32")
    assert tuple(mesh.axis_names) == ("data", "model")
    assert (mesh.shape["data"], mesh.shape["model"]) == (4, 2)
    assert strategy.seq_parallel and not strategy.remat
    assert strategy.dtype == "float32"      # override passed through


def test_plan_summary_formats():
    p = plan(CFG, SHAPE, chips=8)
    compact = p.summary(compact=True)
    assert compact.startswith("dp") and " tp" in compact and " pp" in compact
    full = p.summary()
    assert compact in full and "MFU" in full and p.method in full


def test_plan_to_session_train_smoke():
    p = plan(CFG, SHAPE, chips=jax.device_count())
    session = Session.from_plan(CFG, p, remat=False, microbatches=1,
                                dtype="float32")
    trainer = session.train(TrainConfig(steps=3, lr=1e-3, log_every=1),
                            global_batch=8, seq_len=32)
    trainer.run()
    assert trainer.step == 3
    assert np.isfinite(trainer.history[-1]["loss"])
    # the session threads the TRAINED params through to generate
    assert session.params is trainer.params
    out = session.generate(np.zeros((2, 8), np.int32), steps=4)
    assert out.shape == (2, 4)


def test_caller_params_survive_training():
    # the train step donates its buffers; the session's own param tree
    # (and anything the caller holds) must not be collateral damage
    session = Session(CFG, Strategy(dtype="float32", remat=False))
    ref = session.params
    trainer = session.train(TrainConfig(steps=1, lr=1e-3),
                            global_batch=4, seq_len=16)
    trainer.run()
    for leaf in jax.tree.leaves(ref):
        np.asarray(leaf)                # raises if the buffer was donated


def test_second_train_continues_from_trained_params():
    session = Session(CFG, Strategy(dtype="float32", remat=False))
    t1 = session.train(TrainConfig(steps=2, lr=1e-3),
                       global_batch=4, seq_len=16)
    t1.run()
    trained = np.asarray(jax.tree.leaves(t1.params)[0]).copy()
    t2 = session.train(TrainConfig(steps=1, lr=0.0),
                       global_batch=4, seq_len=16)
    t2.run()
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(t2.params)[0]),
                               trained, atol=1e-6)


def test_restore_survives_an_optimizer_step(tmp_path):
    ckpt = str(tmp_path / "ck")
    session = Session(CFG, Strategy(dtype="float32", remat=False))
    t1 = session.train(TrainConfig(steps=2, lr=1e-2, checkpoint_every=2,
                                   checkpoint_dir=ckpt),
                       global_batch=4, seq_len=16)
    t1.run()
    saved = np.asarray(jax.tree.leaves(t1.params)[0]).copy()

    fresh = Session(CFG, Strategy(dtype="float32", remat=False))
    t2 = fresh.train(TrainConfig(steps=1, lr=0.0, checkpoint_dir=ckpt),
                     global_batch=4, seq_len=16, restore=True)
    assert t2.step == 2
    t2.run(1)
    # adamw derives params from its fp32 master — a stale master would
    # silently revert the restore here
    np.testing.assert_allclose(np.asarray(jax.tree.leaves(t2.params)[0]),
                               saved, atol=1e-6)


def test_session_serve_matches_direct_engine():
    session = Session(CFG, Strategy(dtype="float32", remat=False))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, CFG.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 7)]

    eng = session.serve(slots=2, max_len=64)
    direct = ServeEngine(CFG, session.params, slots=2, max_len=64)
    for i, pr in enumerate(prompts):
        eng.submit(i, pr, max_new=6)
        direct.submit(i, pr, max_new=6)
    got, want = eng.run(), direct.run()
    assert set(got) == set(want) == set(range(len(prompts)))
    for i in want:
        assert got[i].done and want[i].done
        assert got[i].out == want[i].out
