"""All attention execution paths agree: full / chunked(masked) / triangle /
sliding-window, incl. GQA and hypothesis-driven shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.models.layers import attention, attention_chunked, attention_full


def _qkv(key, b, s, hq, hkv, d):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, hq, d)),
            jax.random.normal(ks[1], (b, s, hkv, d)),
            jax.random.normal(ks[2], (b, s, hkv, d)))


@pytest.mark.parametrize("impl", ["chunked", "triangle"])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_chunked_matches_full(impl, hq, hkv):
    q, k, v = _qkv(jax.random.key(0), 2, 256, hq, hkv, 32)
    full = attention_full(q, k, v, causal=True)
    other = attention(q, k, v, causal=True, impl=impl, q_chunk=64,
                      kv_chunk=64)
    np.testing.assert_allclose(other, full, atol=1e-5, rtol=1e-5)


def test_sliding_window_matches_full():
    q, k, v = _qkv(jax.random.key(1), 1, 256, 4, 4, 32)
    full = attention_full(q, k, v, causal=True, window=64)
    chunked = attention_chunked(q, k, v, causal=True, window=64,
                                q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(chunked, full, atol=1e-5, rtol=1e-5)


def test_window_truly_limits_receptive_field():
    """Perturbing a key outside the window must not change the output."""
    q, k, v = _qkv(jax.random.key(2), 1, 256, 2, 2, 16)
    w = 32
    out1 = attention_chunked(q, k, v, causal=True, window=w, q_chunk=64,
                             kv_chunk=64)
    k2 = k.at[:, 10].add(100.0)    # position 10 is outside window of q>=42+
    v2 = v.at[:, 10].add(100.0)
    out2 = attention_chunked(q, k2, v2, causal=True, window=w, q_chunk=64,
                             kv_chunk=64)
    np.testing.assert_allclose(out1[:, 10 + w:], out2[:, 10 + w:],
                               atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=hst.sampled_from([64, 128, 192]),
       hq=hst.sampled_from([2, 4]),
       seed=hst.integers(0, 2**30))
def test_chunked_property(s, hq, seed):
    q, k, v = _qkv(jax.random.key(seed), 1, s, hq, hq, 16)
    full = attention_full(q, k, v, causal=True)
    chunked = attention_chunked(q, k, v, causal=True, q_chunk=64,
                                kv_chunk=64)
    np.testing.assert_allclose(chunked, full, atol=1e-4, rtol=1e-4)


def test_decode_with_kv_len_matches_prefix():
    """Masked decode over a padded cache == attention over the true prefix."""
    b, t, h, d = 2, 64, 2, 16
    key = jax.random.key(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    k = jax.random.normal(ks[1], (b, t, h, d))
    v = jax.random.normal(ks[2], (b, t, h, d))
    n_valid = 40
    out_masked = attention_full(q, k, v, causal=True, q_offset=n_valid - 1,
                                kv_len=jnp.asarray(n_valid))
    out_exact = attention_full(q, k[:, :n_valid], v[:, :n_valid],
                               causal=True, q_offset=n_valid - 1)
    np.testing.assert_allclose(out_masked, out_exact, atol=1e-5)
