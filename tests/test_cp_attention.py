"""Context-parallel decode attention (models/cp_attention.py) must be
numerically exact vs the reference decode path, for both pure-TP and
data x model meshes."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.core import sharding as shd
from repro.core.pspec import sharding_rules
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.launch.mesh import make_mesh

TOL = 5e-4


@pytest.mark.parametrize("mesh_shape", [(1, 8), (2, 4)])
def test_cp_decode_matches_reference(mesh_shape):
    cfg = get_smoke("qwen3-14b").with_(dtype="float32")   # GQA kv=2
    mod = get_model(cfg)
    key = jax.random.key(0)
    params = mod.init(key, cfg)
    B, S = 4, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = mod.forward(params, {"tokens": toks}, cfg)
    cache = mod.init_cache(cfg, B, S)
    lg, cache0 = mod.prefill(params, {"tokens": toks[:, :S - 4]}, cfg, cache)

    cfg_cp = cfg.with_(cp_decode=True)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    st = Strategy(remat=False, dtype="float32")
    with sharding_rules(mesh, st.rules(mesh)):
        csh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.cache_pspecs(cache0, st, mesh, B))
        psh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.param_pspecs(params, st, mesh))
        step = jax.jit(lambda p, c, t, i: mod.decode_step(p, c, t, i, cfg_cp),
                       in_shardings=(psh, csh, None, None))
        c = jax.device_put(cache0, csh)
        for i in range(4):
            pos = S - 4 + i
            with sharding_rules(mesh, st.rules(mesh)):
                lg, c = step(params, c, toks[:, pos:pos + 1],
                             jnp.asarray(pos, jnp.int32))
            err = float(jnp.abs(lg[:, 0] - full[:, pos]).max())
            assert err < TOL, (pos, err)


def test_cp_collective_volume_tiny():
    """The whole point: collectives move O(B*Hq*D) per layer, not the cache.
    Count collective bytes in the lowered HLO and bound them."""
    from repro.launch.hlo_analysis import analyze
    cfg = get_smoke("qwen3-14b").with_(dtype="float32", cp_decode=True)
    mod = get_model(cfg)
    key = jax.random.key(1)
    params = jax.eval_shape(lambda: mod.init(key, cfg))
    B, S = 8, 64
    cache = jax.eval_shape(lambda: mod.init_cache(cfg, B, S))
    mesh = make_mesh((1, 8), ("data", "model"))
    st = Strategy(remat=False, dtype="float32")
    with sharding_rules(mesh, st.rules(mesh)):
        csh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.cache_pspecs(cache, st, mesh, B))
        psh = jax.tree.map(lambda sp: jax.NamedSharding(mesh, sp),
                           shd.param_pspecs(params, st, mesh))
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        comp = jax.jit(
            lambda p, c, t, i: mod.decode_step(p, c, t, i, cfg),
            in_shardings=(psh, csh, None, None)).lower(
                params, cache, tok, pos).compile()
    s = analyze(comp.as_text())
    cache_bytes = B * S * cfg.num_kv_heads * cfg.head_dim * 4
    gathers = s.collectives.get("all-gather", 0)
    # no full-cache gathers: bound well below ONE cache worth of traffic
    assert gathers < cache_bytes, (s.collectives, cache_bytes)
