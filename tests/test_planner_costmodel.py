"""Planner + cost model: legality invariants (hypothesis), Korthikanti
activation-memory numbers, search-method agreement."""
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as hst  # noqa: E402

from repro.configs import SHAPES, get_config, get_smoke
from repro.core.costmodel import (Degrees, V5E, activation_bytes_per_layer,
                                  estimate)
from repro.core.planner import legal_degrees, plan, SEARCH_METHODS


def test_legal_degrees_partition_chips():
    cfg = get_config("qwen3-14b")
    shape = SHAPES["train_4k"]
    for deg in legal_degrees(cfg, shape, 64):
        assert deg.dp * deg.tp * deg.pp == 64
        assert shape.global_batch % deg.dp == 0
        assert (shape.global_batch // deg.dp) % deg.microbatches == 0
        assert deg.pp <= cfg.num_layers


@settings(max_examples=12, deadline=None)
@given(chips=hst.sampled_from([8, 16, 64, 256]),
       arch=hst.sampled_from(["qwen3-14b", "olmoe-1b-7b", "mamba2-780m"]))
def test_estimate_terms_positive_and_finite(chips, arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    for deg in legal_degrees(cfg, shape, chips)[:8]:
        cb = estimate(cfg, shape, deg, V5E)
        assert cb.t_compute > 0 and cb.step_time > 0
        assert cb.t_memory >= 0 and cb.t_collective >= 0
        assert 0 <= cb.bubble_fraction < 1
        assert 0 <= cb.mfu <= 2.5   # SSD archs exceed the 6ND proxy


def test_more_chips_never_slower():
    """Scaling out with the best strategy shouldn't increase step time."""
    cfg = get_config("qwen3-14b")
    shape = SHAPES["train_4k"]
    t64 = plan(cfg, shape, 64).cost
    t256 = plan(cfg, shape, 256).cost
    assert t256 <= t64 * 1.05


def test_korthikanti_formulas():
    """Paper §5.1: the SP formula at t=1 equals the no-SP formula at t=1,
    and SP strictly dominates for t>1 (for realistic a·s/h)."""
    cfg = get_config("qwen3-14b")
    s, b = 4096, 1
    base_t1 = activation_bytes_per_layer(cfg, b, s, 1, False)
    sp_t1 = activation_bytes_per_layer(cfg, b, s, 1, True)
    # t=1: 10 + 24 + 5as/h == 34 + 5as/h
    assert base_t1 == pytest.approx(sp_t1)
    for t in (2, 4, 8, 16):
        assert (activation_bytes_per_layer(cfg, b, s, t, True)
                < activation_bytes_per_layer(cfg, b, s, t, False))
    # SP removes the un-parallelised 10·s·b·h floor:
    t = 8
    no_sp = activation_bytes_per_layer(cfg, b, s, t, False)
    sp = activation_bytes_per_layer(cfg, b, s, t, True)
    floor = 10 * s * b * cfg.d_model
    assert no_sp - sp == pytest.approx(floor * (1 - 1 / t), rel=1e-6)


@pytest.mark.parametrize("method", list(SEARCH_METHODS))
def test_search_methods_return_feasible(method):
    cfg = get_config("minitron-4b")
    p = plan(cfg, SHAPES["train_4k"], 256, method=method)
    assert p.fits
    assert p.degrees.dp * p.degrees.tp * p.degrees.pp == 256
    assert p.cost > 0


def test_search_quality_ordering():
    """Exhaustive is the floor; dp/mcmc must come within 25%."""
    cfg = get_config("internlm2-20b")
    shape = SHAPES["train_4k"]
    best = plan(cfg, shape, 256, method="exhaustive").cost
    for m in ("dp", "mcmc"):
        assert plan(cfg, shape, 256, method=m).cost <= best * 1.25


def test_moe_planner_uses_ep():
    cfg = get_config("olmoe-1b-7b")
    p = plan(cfg, SHAPES["train_4k"], 256)
    assert p.degrees.ep == p.degrees.tp
