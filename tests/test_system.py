"""End-to-end behaviour: a small LM trains (loss drops) and serves
(greedy decode continues a learned motif); the trainer integrates data,
sharding, optimizer, metrics and checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.data.pipeline import DataConfig, TokenDataset
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.serve.step import greedy_generate
from repro.train.trainer import TrainConfig, Trainer

TINY = ModelConfig(name="tiny-lm", arch_type="dense", num_layers=2,
                   d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                   vocab_size=256, dtype="float32")


def test_training_reduces_loss():
    mesh = make_host_mesh(model=1)
    tr = Trainer(TINY, Strategy(remat=False, microbatches=1,
                                dtype="float32"),
                 mesh, TrainConfig(steps=60, lr=1e-3, log_every=20),
                 global_batch=8, seq_len=64)
    tr.run()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] * 0.85, losses


def test_trainer_checkpoint_cycle(tmp_path):
    mesh = make_host_mesh(model=1)
    tc = TrainConfig(steps=12, lr=1e-3, log_every=6, checkpoint_every=6,
                     checkpoint_dir=str(tmp_path))
    tr = Trainer(TINY, Strategy(remat=False, dtype="float32"), mesh, tc,
                 global_batch=4, seq_len=32)
    tr.run()
    tr2 = Trainer(TINY, Strategy(remat=False, dtype="float32"), mesh, tc,
                  global_batch=4, seq_len=32)
    assert tr2.maybe_restore() == 12
    a = jax.tree.leaves(tr.params)
    b = jax.tree.leaves(tr2.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_greedy_generation_shapes():
    model = get_model(TINY)
    params = model.init(jax.random.key(0), TINY)
    prompt = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    out = greedy_generate(params, TINY, Strategy(), prompt, steps=5)
    assert out.shape == (2, 5)
    assert int(out.max()) < TINY.vocab_size
