"""Assemble the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run JSON artifacts. §Claims and §Perf are maintained by hand.

    PYTHONPATH=src python experiments/build_report.py > experiments/roofline.md
"""
import json
from pathlib import Path

HERE = Path(__file__).resolve().parent
DRY = HERE / "dryrun"


def fmt(x, nd=2):
    if x == 0:
        return "0"
    if abs(x) >= 1000 or abs(x) < 0.01:
        return f"{x:.{nd}e}"
    return f"{x:.{nd}f}"


def load(tag: str, mesh: str):
    rows = []
    for fn in sorted(DRY.glob(f"*__{mesh}__{tag}.json")):
        rows.append(json.loads(fn.read_text()))
    return rows


def roofline_table(rows):
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "bottleneck | useful | MFU-UB | mem/dev (GB) | fits |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        if rec.get("status") != "ok":
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"{rec['status']} | — | — | — | — |")
            continue
        r = rec["roofline"]
        fits = "yes" if r["mem_per_device_gb"] < 16 else "**NO**"
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_upper_bound']:.3f} | "
            f"{r['mem_per_device_gb']:.1f} | {fits} |")
    return "\n".join(out)


def dryrun_table(rows):
    out = ["| arch | shape | strategy | compile (s) | args (GB/dev) | "
           "temp (GB/dev) | collectives (counts) |",
           "|---|---|---|---|---|---|---|"]
    for rec in rows:
        if rec.get("status") != "ok":
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | — | "
                       f"{rec['status']} |")
            continue
        m = rec["memory_analysis"]
        sd = rec["strategy_detail"]
        stra = (f"{'sp ' if sd['seq_parallel'] else ''}"
                f"{'fsdp ' if sd['fsdp'] else ''}{sd['optimizer']} "
                f"m{sd['microbatches']}")
        counts = rec["roofline"]["coll_detail"].get("counts", {})
        cstr = " ".join(f"{k.split('-')[0][:2]}{k.split('-')[-1][:3]}:{v}"
                        for k, v in counts.items() if v)
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {stra} | "
            f"{rec['compile_s']} | "
            f"{(m['argument_size_in_bytes'] or 0) / 1e9:.2f} | "
            f"{(m['temp_size_in_bytes'] or 0) / 1e9:.2f} | {cstr} |")
    return "\n".join(out)


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = load("base", mesh)
        if not rows:
            continue
        chips = 256 if mesh == "pod16x16" else 512
        print(f"\n### Roofline — {mesh} ({chips} chips, baseline strategy)\n")
        print(roofline_table(rows))
    opt = load("opt", "pod16x16")
    if opt:
        print("\n### Roofline — pod16x16, beyond-paper optimized strategy "
              "(SP + CP-decode + triangle prefill + bf16 accum)\n")
        print(roofline_table(opt))
    print("\n### Dry-run detail — pod16x16 (baseline)\n")
    print(dryrun_table(load("base", "pod16x16")))
    print("\n### Dry-run detail — pod2x16x16 (multi-pod, baseline)\n")
    print(dryrun_table(load("base", "pod2x16x16")))


if __name__ == "__main__":
    main()
