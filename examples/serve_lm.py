"""Serving example: batched prefill + decode with a KV cache (greedy),
including a sliding-window variant whose cache stays O(window).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.strategy import Strategy
from repro.models import get_model
from repro.serve.step import greedy_generate


def main():
    cfg = ModelConfig(name="serve-demo", arch_type="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=2048, dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.key(0), cfg)

    # batched requests: 8 prompts of 32 tokens, 16 new tokens each
    b, s, new = 8, 32, 16
    prompt = {"tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                           cfg.vocab_size)}
    t0 = time.time()
    out = greedy_generate(params, cfg, Strategy(), prompt, steps=new)
    dt = time.time() - t0
    print(f"batch={b} prompt={s} decoded={new} tokens "
          f"in {dt:.2f}s -> {b * new / dt:.1f} tok/s")
    print("sample:", out[0].tolist())

    # sliding-window serving: the cache is a ring of `window` slots
    swa = cfg.with_(sliding_window=16, name="serve-demo-swa")
    cache = get_model(swa).init_cache(swa, b, s + new)
    print(f"\nSWA cache ring length: {cache['kv']['k'].shape[2]} "
          f"(vs {s + new} linear) — O(window) decode memory")
    out2 = greedy_generate(params, swa, Strategy(), prompt, steps=new)
    print("SWA sample:", out2[0].tolist())


if __name__ == "__main__":
    main()
