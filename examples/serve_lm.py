"""Serving example: batched prefill + decode with a KV cache (greedy),
including a sliding-window variant whose cache stays O(window). Both run
through the Session facade; the SWA session REUSES the dense session's
params — param threading is the Session's job, not the caller's.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.api import Session
from repro.configs.base import ModelConfig
from repro.models import get_model


def main():
    cfg = ModelConfig(name="serve-demo", arch_type="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
                      vocab_size=2048, dtype="float32")
    session = Session(cfg)

    # batched requests: 8 prompts of 32 tokens, 16 new tokens each
    b, s, new = 8, 32, 16
    prompt = jax.random.randint(jax.random.key(1), (b, s), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = session.generate(prompt, steps=new)
    dt = time.time() - t0
    print(f"batch={b} prompt={s} decoded={new} tokens "
          f"in {dt:.2f}s -> {b * new / dt:.1f} tok/s")
    print("sample:", out[0].tolist())

    # sliding-window serving: the cache is a ring of `window` slots
    swa = cfg.with_(sliding_window=16, name="serve-demo-swa")
    cache = get_model(swa).init_cache(swa, b, s + new)
    print(f"\nSWA cache ring length: {cache['kv']['k'].shape[2]} "
          f"(vs {s + new} linear) — O(window) decode memory")
    swa_session = Session(swa, params=session.params)
    out2 = swa_session.generate(prompt, steps=new)
    print("SWA sample:", out2[0].tolist())

    # continuous batching: independent requests at different depths share
    # ONE batched jitted decode step (the slot table), so the whole run
    # compiles a single decode program no matter how slots churn
    import numpy as np
    eng = session.serve(slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(6):
        n = int(rng.integers(4, 20))
        eng.submit(rid, rng.integers(0, cfg.vocab_size, size=(n,)),
                   max_new=12)
    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    total = sum(len(r.out) for r in results.values())
    print(f"\nengine: {len(results)} requests, {total} tokens in {dt:.2f}s "
          f"({eng.stats['decode_steps']} batched decode calls, "
          f"{eng.stats['decode_traces']} trace)")
    print("req 0:", results[0].out)


if __name__ == "__main__":
    main()
