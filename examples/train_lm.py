"""End-to-end driver (deliverable b): train a ~100M-parameter dense LM for a
few hundred steps on the synthetic corpus, with sharding, checkpointing and
metrics — the full production path at laptop scale, behind the Session
facade.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]
"""
import argparse

from repro.api import Session, Strategy, TrainConfig
from repro.configs.base import ModelConfig


def build_config(d_model: int) -> ModelConfig:
    # ~100M params at d_model=640: 12L, vocab 8k
    return ModelConfig(name="lm-100m", arch_type="dense", num_layers=12,
                       d_model=d_model, num_heads=d_model // 64,
                       num_kv_heads=max(1, d_model // 128),
                       d_ff=4 * d_model, vocab_size=8192, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=640)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--checkpoint-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    cfg = build_config(args.d_model)
    n = cfg.param_count()
    print(f"model: {cfg.name} — {n/1e6:.1f}M params, "
          f"{cfg.num_layers}L d={cfg.d_model}")

    session = Session(cfg, Strategy(remat=False, microbatches=2,
                                    dtype="float32"))
    tc = TrainConfig(steps=args.steps, lr=6e-4, log_every=20,
                     checkpoint_every=max(args.steps // 3, 1),
                     checkpoint_dir=args.checkpoint_dir)
    trainer = session.train(tc, global_batch=args.batch, seq_len=args.seq,
                            restore=True)
    trainer.run()
    first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({(1 - last / first):.0%} reduction)")


if __name__ == "__main__":
    main()
