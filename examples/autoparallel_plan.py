"""Auto-parallelisation demo: plan every assigned architecture x shape on
the production pod and print the strategy table (paper §4 made concrete).

    PYTHONPATH=src python examples/autoparallel_plan.py [--method dp]
"""
import argparse

from repro.api import plan
from repro.configs import ARCH_NAMES, SHAPES, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="dp",
                    choices=["exhaustive", "dp", "mcmc"])
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    hdr = (f"{'arch':24s} {'shape':12s} {'plan':26s} "
           f"{'est step':>9s} {'MFU':>6s} fits")
    print(hdr)
    print("-" * len(hdr))
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape_name in ("train_4k", "prefill_32k"):
            shape = SHAPES[shape_name]
            p = plan(cfg, shape, args.chips, method=args.method)
            desc = p.summary(compact=True)
            print(f"{arch:24s} {shape_name:12s} {desc:26s} "
                  f"{p.cost:8.3f}s {p.mfu:6.1%} {p.fits}")


if __name__ == "__main__":
    main()
