"""Quickstart: build a small dense LM, auto-plan its parallelisation,
materialize the plan, then train / generate through ONE Session facade —
the survey's §4 loop (search -> evaluate -> execute) end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.api import Session, TrainConfig, plan
from repro.configs.base import ModelConfig, ShapeConfig


def main():
    cfg = ModelConfig(name="quickstart-20m", arch_type="dense",
                      num_layers=4, d_model=256, num_heads=8,
                      num_kv_heads=4, d_ff=1024, vocab_size=2048,
                      dtype="float32")

    # 1) ask the auto-parallelisation planner what it would do on a pod
    pod = plan(cfg, ShapeConfig("train", 2048, 256, "train"), chips=256)
    print(f"planner (256 chips): {pod.summary()}\n")

    # 2) plan for the devices we actually have, materialize it into a
    #    (Strategy, Mesh) pair, and train for real through the Session
    host = plan(cfg, ShapeConfig("host", 128, 8, "train"),
                chips=jax.device_count())
    session = Session.from_plan(cfg, host, remat=False, microbatches=1,
                                dtype="float32")
    trainer = session.train(TrainConfig(steps=40, lr=1e-3, log_every=10),
                            global_batch=8, seq_len=128)
    trainer.run()

    # 3) greedy-decode a continuation — the session threads the TRAINED
    #    params through, no manual param plumbing
    prompt = trainer.data.batch(0)["tokens"][:2, :16]
    out = session.generate(prompt, steps=8)
    print("\ngenerated continuation tokens:\n", out)


if __name__ == "__main__":
    main()
