"""Quickstart: build a small dense LM, auto-plan its parallelisation, train
a few steps, and generate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.planner import plan
from repro.core.strategy import Strategy
from repro.launch.mesh import make_host_mesh
from repro.serve.step import greedy_generate
from repro.train.trainer import TrainConfig, Trainer


def main():
    cfg = ModelConfig(name="quickstart-20m", arch_type="dense",
                      num_layers=4, d_model=256, num_heads=8,
                      num_kv_heads=4, d_ff=1024, vocab_size=2048,
                      dtype="float32")

    # 1) ask the auto-parallelisation planner what it would do on a pod
    p = plan(cfg, ShapeConfig("train", 2048, 256, "train"), chips=256)
    d = p.degrees
    print(f"planner (256 chips): dp={d.dp} tp={d.tp} pp={d.pp} "
          f"micro={d.microbatches} sp={d.seq_parallel} "
          f"-> est step {p.cost:.3f}s, MFU {p.mfu:.1%}\n")

    # 2) train for real on the local devices
    mesh = make_host_mesh(model=1)
    trainer = Trainer(cfg, Strategy(remat=False, dtype="float32"),
                      mesh, TrainConfig(steps=40, lr=1e-3, log_every=10),
                      global_batch=8, seq_len=128)
    trainer.run()

    # 3) greedy-decode a continuation
    prompt = {"tokens": trainer.data.batch(0)["tokens"][:2, :16]}
    out = greedy_generate(trainer.params, cfg, Strategy(), prompt, steps=8)
    print("\ngenerated continuation tokens:\n", out)


if __name__ == "__main__":
    main()
